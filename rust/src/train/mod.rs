//! The distributed training coordinator (§3 workflow).
//!
//! [`Trainer`] spawns one worker thread per simulated GPU and runs the
//! synchronous hybrid-parallel loop end to end, with every MTGRBoost
//! feature toggleable for the §6 ablations:
//!
//! 1. **Data I/O** — per-worker seeded generator shard feeding the
//!    batcher ([`crate::balance`]) through a prefetcher.
//! 2. **Embedding lookup** — occurrence ids ([`features::BatchIds`]),
//!    split per merge group ([`crate::embedding::merge::MergePlan`];
//!    one physical shard table, exchange, and optimizer per group, in
//!    group order), through the model-parallel sharded exchange with
//!    two-stage dedup ([`crate::embedding::sharded`]). Homogeneous
//!    schemas form one group — byte-identical to the historical
//!    single-table path.
//! 3. **Forward/Backward** — the AOT train artifact on the PJRT engine
//!    (data parallelism: every worker holds a dense replica).
//! 4. **Backward update** — sparse: gradient all-to-all onto the owning
//!    shard + row-wise Adam; dense: batch-size all-gather, weighted
//!    all-reduce (§5.1), Adam.
//!
//! Wall-clock phases are measured per worker; *simulated* device/step
//! times are accounted via [`crate::metrics::DeviceModel`] +
//! [`crate::collective::NetModel`] so single-host runs report the paper's
//! multi-GPU quantities (who waits for whom, where time goes).

pub mod features;

use std::sync::Arc;

use anyhow::{Context, Result};

use crate::balance::{Batch, Batcher, DynamicBatcher, FixedBatcher};
use crate::collective::comm::{CommGroup, CommHandle, LANES};
use crate::collective::netmodel::NetModel;
use crate::config::{ClusterConfig, ModelConfig, TrainConfig};
use crate::checkpoint::delta::DeltaMeta;
use crate::data::generator::GeneratorConfig;
use crate::data::schema::Schema;
use crate::embedding::concurrent::ConcurrentDynamicTable;
use crate::embedding::dynamic_table::{DynamicTableConfig, TableStats};
use crate::embedding::merge::MergePlan;
use crate::embedding::sharded::{GroupExchange, MultiBackward, MultiLookup, ShardedEmbedding};
use crate::embedding::dedup::DedupVolume;
use crate::embedding::GlobalId;
use crate::metrics::{DeviceModel, GaucAccumulator, Throughput};
use crate::online::stream::StreamingSource;
use crate::online::{FeatureAdmission, OnlineOptions, OnlineTable};
use crate::optim::adam::{AdamParams, DenseAdam, SparseAdam};
use crate::optim::{DenseAccumulator, SparseAccumulator};
use crate::runtime::{Engine, TrainScratch};
use crate::util::pool::WorkerPool;
use crate::util::timer::PhaseTimer;
use features::BatchIds;

/// Everything a training run needs.
#[derive(Clone)]
pub struct TrainerOptions {
    pub model: String,
    pub cluster: ClusterConfig,
    pub train: TrainConfig,
    pub generator: GeneratorConfig,
    pub device: DeviceModel,
    pub net: NetModel,
    pub steps: usize,
    /// Overlap micro-batch *k+1*'s ID all-to-all with micro-batch *k*'s
    /// compute (two-phase `post_ids`/`complete_lookup` pipeline). Off
    /// reproduces the strictly sequential baseline; the numerics are
    /// bit-identical either way (ablation axis for Fig. 12).
    pub overlap: bool,
    /// Extend the double buffer across *step boundaries*, in both
    /// directions: step s+1's first ID all-to-all posts before step s's
    /// dense all-reduce + optimizer apply
    /// (`StepRecord::sim_hidden_boundary_s`), and step s's last gradient
    /// push stays in flight across the dense all-reduce, completing only
    /// right before the sparse optimizer needs its sums
    /// (`StepRecord::sim_hidden_boundary_grad_s`). Requires `overlap`;
    /// numerics are bit-identical on or off (`--cross-step`).
    pub cross_step: bool,
    /// Pack all merge groups' exchange payloads into ONE message per
    /// comm lane ([`crate::embedding::sharded::GroupExchange`]) instead
    /// of one all-to-all per group — per-message latency stops scaling
    /// with the group count. Single-group schemas keep the historical
    /// wire format byte for byte either way, and numerics are
    /// bit-identical on or off (`--no-multiplex` disables).
    pub multiplex_exchange: bool,
    /// Fold same-dim logical tables into one physical table per merge
    /// group (§4.2). `false` (`--no-merging`) keeps one group per
    /// logical table — the unmerged ablation baseline; global ids are
    /// identical, so numerics match bitwise.
    pub table_merging: bool,
    /// Threads in the **process-global** worker pool shared by every
    /// trainer worker (dense forward/backward chunking, dedup, stage-2
    /// serve fan-out over table stripes, row expansion, gradient
    /// aggregation, optimizer apply). Each worker runs on a
    /// deterministic fair-share view (`⌈threads/world⌉`), so the host
    /// is never oversubscribed at `world × threads`. 1 = serial
    /// reference, 0 = size to the machine; results are bit-identical
    /// for every value (`--threads`).
    pub threads: usize,
    /// Batches buffered ahead of the consumer by the data prefetcher.
    pub prefetch_depth: usize,
    /// Initial capacity of each worker's table shard.
    pub shard_capacity: usize,
    /// Collect GAUC during training (costs memory on long runs).
    pub collect_gauc: bool,
    /// Skip the first N steps when accumulating GAUC (predictions from
    /// an untrained model only add noise to the running metric).
    pub gauc_warmup: usize,
    pub log_every: usize,
    /// `Some` switches the trainer into **online** mode: an endless
    /// time-stamped stream (new IDs arriving per generator day) with
    /// feature admission in front of sparse insertion, a TTL sweeper
    /// retiring stale rows, and an incremental delta sync every
    /// `sync_interval` steps. `steps` is ignored — the run is bounded
    /// by `intervals × sync_interval` (or endless when `intervals` is
    /// 0). Numerics stay bit-identical across `--threads` values.
    /// Online knobs (admission, TTL, sync cadence) apply **uniformly to
    /// every merge group** — there are no per-group policies.
    pub online: Option<OnlineOptions>,
    /// Feature-schema preset (`--schema`): `"meituan"` (homogeneous
    /// dims — one merge group, the historical path, byte-identical to
    /// pre-multi-group builds) or `"meituan-mixed"` (8D context + d-dim
    /// token features with a `shared_table` alias — ≥ 2 merge groups,
    /// one physical shard table, exchange and optimizer per group).
    pub schema: String,
    /// `Some` applies a named workload scenario (`--scenario`): the
    /// preset reshapes the generator distribution, may force a schema
    /// (`multi-tenant` → `meituan-tiered`), install per-group row
    /// budgets, and fill online defaults (day cadence, admission decay
    /// + re-admission hysteresis, soak TTL). Scenarios compose with —
    /// never fork — the existing stream/online stack, and numerics stay
    /// bit-identical across `--threads`/`--overlap`/`--cross-step`
    /// under every preset.
    pub scenario: Option<crate::scenario::Scenario>,
    /// `Some` marks this process as one rank of a **multi-process**
    /// run ([`crate::dist`]): resume-from-delta replay plus per-step /
    /// per-interval callbacks (heartbeats, coordinator barrier, fault
    /// injection). `None` — the default — is the single-process path,
    /// untouched byte for byte.
    pub dist: Option<DistTrainOptions>,
    /// Embedding storage/wire precision (`--precision`): `Fp32` — the
    /// default — is byte-identical to the pre-policy system; `Mixed`
    /// stores hot rows (post-bump access count ≥ `hot_threshold`) at
    /// FP32 and cold rows on the binary16 grid, and compresses cold
    /// reply rows and gradient pushes to FP16 on the wire. Applies
    /// uniformly to every merge group; numerics are bit-identical
    /// across `--threads`/`--overlap`/`--cross-step`/multiplexing for
    /// either mode.
    pub precision: crate::embedding::precision::PrecisionMode,
    /// Post-bump access-count threshold separating FP32 hot rows from
    /// FP16 cold rows under `--precision mixed` (`--hot-threshold`).
    pub hot_threshold: u32,
}

impl TrainerOptions {
    pub fn new(model: &str, world: usize, steps: usize) -> Self {
        TrainerOptions {
            model: model.to_string(),
            cluster: ClusterConfig::new(world),
            train: TrainConfig::default(),
            generator: GeneratorConfig::default(),
            device: DeviceModel::default(),
            net: NetModel::default(),
            steps,
            overlap: true,
            cross_step: true,
            multiplex_exchange: true,
            table_merging: true,
            threads: 1,
            prefetch_depth: 2,
            shard_capacity: 4096,
            collect_gauc: true,
            gauc_warmup: 0,
            log_every: 0,
            online: None,
            schema: "meituan".to_string(),
            scenario: None,
            dist: None,
            precision: crate::embedding::precision::PrecisionMode::Fp32,
            hot_threshold: 8,
        }
    }

    /// The per-table precision policy the options select.
    pub fn precision_policy(&self) -> crate::embedding::precision::PrecisionPolicy {
        crate::embedding::precision::PrecisionPolicy::from_mode(self.precision, self.hot_threshold)
    }

    /// The schema actually trained on: the scenario's forced preset
    /// when it has one, else `--schema`.
    pub fn effective_schema(&self) -> &str {
        self.scenario
            .as_ref()
            .and_then(|s| s.schema_override)
            .unwrap_or(&self.schema)
    }

    /// Reject contradictory option combinations before any thread
    /// spawns (also the backing check for the CLI's flag validation).
    pub fn validate(&self) -> Result<()> {
        if let Some(sc) = &self.scenario {
            sc.validate(self.online.is_some())?;
            if let Some(forced) = sc.schema_override {
                anyhow::ensure!(
                    self.schema == "meituan" || self.schema == forced,
                    "scenario `{}` forces --schema {forced}; drop the conflicting \
                     --schema {}",
                    sc.name,
                    self.schema
                );
            }
            anyhow::ensure!(
                self.dist.is_none(),
                "scenarios are not supported in dist mode (admission/TTL \
                 presets conflict with delta-chain recovery)"
            );
        }
        anyhow::ensure!(
            Schema::is_preset(self.effective_schema()),
            "unknown schema preset `{}` (expected one of {:?})",
            self.effective_schema(),
            Schema::preset_names()
        );
        if let Some(o) = &self.online {
            o.validate()?;
        } else {
            anyhow::ensure!(self.steps > 0, "offline runs need --steps > 0");
        }
        if self.precision == crate::embedding::precision::PrecisionMode::Mixed {
            anyhow::ensure!(
                self.hot_threshold >= 1,
                "--precision mixed needs --hot-threshold >= 1 (0 would pin every \
                 row hot and never compress)"
            );
        }
        if self.dist.is_some() {
            // Multi-process runs lean on the delta chain as the ONLY
            // recovery substrate: every resident row must appear in
            // some delta ≤ R for replay to be exact, which rules out
            // admission (rows trained but never inserted) and TTL
            // (rows retired between syncs). GAUC accumulates unmerged
            // per-process state the supervisor cannot combine.
            let o = self
                .online
                .as_ref()
                .ok_or_else(|| anyhow::anyhow!("dist runs require --mode online"))?;
            anyhow::ensure!(
                o.sync_dir.is_some(),
                "dist runs require --sync-dir (the delta chain is the recovery substrate)"
            );
            anyhow::ensure!(
                o.intervals > 0,
                "dist runs need bounded --intervals (> 0)"
            );
            anyhow::ensure!(
                o.feature_ttl == 0,
                "dist runs do not support --feature-ttl (expired rows would be \
                 unrecoverable from the delta chain)"
            );
            anyhow::ensure!(
                o.admission.is_none(),
                "dist runs do not support feature admission (rejected-row state \
                 would be unrecoverable from the delta chain)"
            );
            anyhow::ensure!(
                !self.collect_gauc,
                "dist runs require --gauc off (per-process GAUC state cannot be merged)"
            );
        }
        Ok(())
    }
}

/// Per-step / per-interval callbacks a multi-process rank installs via
/// [`TrainerOptions::dist`]. The trainer stays ignorant of sockets,
/// heartbeats and fault plans — `dist` implements them behind this
/// trait, so `train` never depends on `dist`.
pub trait DistHooks: Send + Sync {
    /// Top of every step, right after the TTL clock advances and before
    /// the first collective of the step — the heartbeat step stamp and
    /// the kill-fault injection point.
    fn on_step(&self, _step: usize) {}

    /// After an online interval's delta publish and counter gathers
    /// (delta `seq` is durable on disk at this point) — the
    /// coordinator's step barrier. An error aborts the run.
    fn on_interval(&self, _seq: u64) -> Result<()> {
        Ok(())
    }
}

/// Multi-process knobs carried inside [`TrainerOptions`].
#[derive(Clone, Default)]
pub struct DistTrainOptions {
    /// Resume point: restore deltas `1..=resume_seq` (plus delta
    /// `resume_seq`'s dense state), replay the data stream past the
    /// covered steps, and start training at step
    /// `resume_seq × sync_interval`. `0` = fresh start.
    pub resume_seq: u64,
    /// Runtime callbacks (heartbeats, barrier, fault injection).
    pub hooks: Option<Arc<dyn DistHooks>>,
}

impl std::fmt::Debug for DistTrainOptions {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("DistTrainOptions")
            .field("resume_seq", &self.resume_seq)
            .field("hooks", &self.hooks.is_some())
            .finish()
    }
}

/// Failure/recovery counters surfaced in [`TrainReport`]. Worker
/// processes account their own transport retries; the supervisor fills
/// in heartbeat misses, recoveries and replayed steps when it merges
/// rank reports ([`crate::dist::supervisor`]). All zero for
/// single-process runs.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DistStats {
    /// Heartbeat intervals that elapsed without a beat (coordinator
    /// view, summed over ranks and incarnations).
    pub heartbeat_misses: u64,
    /// Transport-level send retries that eventually succeeded
    /// (connect retries + injected transient faults).
    pub transport_retries: u64,
    /// Gang restarts the supervisor performed.
    pub recoveries: u64,
    /// Steps re-run because they fell after the newest durable delta
    /// at recovery time.
    pub replayed_steps: u64,
}

/// Per-step record (identical on every worker; rank 0's copy returned).
#[derive(Clone, Debug)]
pub struct StepRecord {
    pub step: usize,
    /// Global mean losses.
    pub loss_ctr: f64,
    pub loss_ctcvr: f64,
    pub samples: u64,
    /// Real token count per worker (Fig. 9 / 15 raw data).
    pub tokens: Vec<u64>,
    /// Simulated per-worker compute+lookup seconds (Fig. 9 shading).
    pub sim_device_s: Vec<f64>,
    /// Simulated per-worker *exposed* communication seconds (emb
    /// exchange + whatever part of the ID exchange compute cannot hide).
    pub sim_exposed_comm_s: Vec<f64>,
    /// Simulated per-worker ID-exchange seconds hidden behind compute
    /// (zero with `overlap: false`) — Fig. 12's overlap decomposition.
    pub sim_hidden_comm_s: Vec<f64>,
    /// Simulated per-worker embedding-reply seconds hidden by the
    /// double-buffered round (zero with `overlap: false`).
    pub sim_hidden_reply_s: Vec<f64>,
    /// Simulated per-worker backward-gradient seconds hidden behind the
    /// next micro-batch's forward (zero with `overlap: false`).
    pub sim_hidden_grad_s: Vec<f64>,
    /// Simulated per-worker ID-exchange seconds hidden behind the
    /// previous step's dense all-reduce + optimizer apply (cross-step
    /// pipelining; zero unless `overlap` and `cross_step` are on).
    pub sim_hidden_boundary_s: Vec<f64>,
    /// Simulated per-worker last-round gradient-push seconds hidden
    /// behind this step's dense all-reduce (the cross-step gradient
    /// lane; zero unless `overlap` and `cross_step` are on).
    pub sim_hidden_boundary_grad_s: Vec<f64>,
    /// Simulated synchronous step seconds (max device + dense sync).
    pub sim_step_s: f64,
    /// Simulated delta-sync push seconds (slowest rank's payload on the
    /// inter-node fabric); nonzero only on online interval boundaries.
    pub sim_sync_s: f64,
    pub wall_s: f64,
    /// Fused lookup operators this step actually issued: one per merge
    /// group per micro round (§4.2 operator fusion). Identical on every
    /// rank (rounds are collectively aligned).
    pub lookup_ops_merged: u64,
    /// Lookup operators the same step would have issued *without* table
    /// merging: one per logical table per micro round. The merged count
    /// is strictly below this whenever any group fuses ≥ 2 tables.
    pub lookup_ops_unmerged: u64,
    /// Online per-interval counters, summed across ranks; populated on
    /// interval-boundary steps of `--mode online` runs, zero otherwise.
    pub online_admitted: u64,
    pub online_rejected: u64,
    pub online_expired: u64,
    pub online_synced_rows: u64,
    pub online_sync_bytes: u64,
    /// Per-lane all-to-all payload bytes this step, summed across ranks
    /// (index = comm lane). Lane 0 also carries collective bookkeeping
    /// traffic; lanes 1–4 carry exactly the sparse exchanges, with the
    /// multiplexed packing headers excluded — so they are conserved
    /// between the multiplexed and per-group paths. Attribution follows
    /// the posting schedule (a cross-step post counts in the step that
    /// posted it).
    pub wire_payload_bytes: Vec<u64>,
    /// Packing-header bytes the multiplexed exchange added this step,
    /// summed across ranks (zero when unmultiplexed or single-group).
    pub wire_header_bytes: u64,
    /// Tokens left buffered in the batcher after this step's batch was
    /// cut (the carry-over), summed across ranks — the scenario
    /// telemetry for adversarial length distributions.
    pub batcher_carryover: u64,
    /// Embedding rows resident across every merge group, summed across
    /// ranks at the step boundary (the soak suite's bounded-memory
    /// witness).
    pub resident_rows: u64,
    /// Generator day the step's batch was drawn from (max across
    /// ranks; 0 until the stream crosses its first day boundary).
    pub online_day: u64,
    /// Row-budget evictions this step (per-step delta of the dynamic
    /// tables' eviction counters, summed across ranks) — the
    /// multi-tenant scenario's capacity-pressure meter.
    pub evictions: u64,
    /// Mixed-precision wire bytes this step by row precision, summed
    /// across ranks and *all* destinations including the local loopback
    /// chunk (a pure function of the served batches — schedule- and
    /// mux-independent, unlike the remote-only lane meters above). All
    /// zero under `--precision fp32`, where the wire format is the
    /// historical one byte for byte.
    pub wire_fp32_row_bytes: u64,
    pub wire_fp16_row_bytes: u64,
    /// Framing the mixed format adds (reply tag bitmasks + gradient-ID
    /// `[n]…[tags]` words).
    pub wire_tag_bytes: u64,
    /// Hot/cold row census across every rank's merge groups at the step
    /// boundary (post-bump classification; zero in fp32 mode, where the
    /// census is skipped).
    pub hot_rows: u64,
    pub cold_rows: u64,
}

/// Aggregated outcome of a run.
#[derive(Debug)]
pub struct TrainReport {
    pub steps: Vec<StepRecord>,
    pub gauc_ctr: Option<f64>,
    pub gauc_ctcvr: Option<f64>,
    pub phases: PhaseTimer,
    pub wall: Throughput,
    /// Simulated throughput (samples/s at simulated step times).
    pub sim_samples_per_sec: f64,
    pub sim_tokens_per_sec: f64,
    pub table_rows: usize,
    pub table_memory_bytes: usize,
    pub dedup_volume: DedupVolume,
    pub truncated_sequences: u64,
    /// Mean data-prefetch queue occupancy at fetch time across workers
    /// (0..=`prefetch_depth`; near the depth means I/O fully masked).
    pub prefetch_occupancy: f64,
    /// Order-independent fingerprint of every worker's final embedding
    /// shard contents (ids + row bits) — the e2e bitwise-equality
    /// witness for `--threads`/`--overlap` ablations.
    pub embedding_checksum: u64,
    /// Aggregate dynamic-table statistics across worker shards
    /// (inserts, probes, expansions, **evictions** — the
    /// memory-pressure counters).
    pub table_stats: TableStats,
    /// Embedding dim of each merge group (len 1 for homogeneous
    /// schemas; the order matches every other `group_*` field).
    pub group_dims: Vec<usize>,
    /// Per-group communication/dedup volumes, summed across workers —
    /// per-group dedup ratios for the table-merge bench.
    pub group_volumes: Vec<DedupVolume>,
    /// Per-group order-independent state fingerprints (summed across
    /// worker shards); `embedding_checksum` is their wrapping sum.
    pub group_checksums: Vec<u64>,
    /// Rows resident per merge group (summed across worker shards).
    pub group_rows: Vec<usize>,
    /// Run totals of the per-step lookup-operator counts.
    pub lookup_ops_merged: u64,
    pub lookup_ops_unmerged: u64,
    /// Online-mode run totals (sums of the per-interval counters in
    /// [`StepRecord`]); all zero for offline runs.
    pub online_admitted: u64,
    pub online_rejected: u64,
    pub online_expired: u64,
    pub online_synced_rows: u64,
    pub online_sync_bytes: u64,
    /// Run totals of the per-step per-lane payload bytes (summed across
    /// ranks and steps; index = comm lane).
    pub wire_payload_bytes: Vec<u64>,
    /// Run total of the multiplexed packing-header bytes.
    pub wire_header_bytes: u64,
    /// Failure/recovery counters (all zero for single-process runs;
    /// the supervisor adds heartbeat misses / recoveries / replayed
    /// steps when merging multi-process rank reports).
    pub dist: DistStats,
    /// Name of the workload scenario the run trained under (`None`
    /// without `--scenario`).
    pub scenario: Option<String>,
    /// Peak of the per-step global resident-row count — the soak
    /// suite asserts this stays bounded over multi-day runs.
    pub peak_resident_rows: u64,
    /// Mean per-step batcher carry-over tokens (summed across ranks).
    pub batcher_carryover_mean: f64,
    /// Mean per-step batcher fill: emitted tokens over
    /// `target_tokens × world` (0.0 under the fixed batcher).
    pub batcher_fill_mean: f64,
    /// Run total of per-step row-budget evictions.
    pub total_evictions: u64,
    /// The precision mode the run trained under (`"fp32"` / `"mixed"`).
    pub precision: String,
    /// Run totals of the mixed-precision wire meters (see
    /// [`StepRecord::wire_fp32_row_bytes`]); all zero under fp32.
    pub wire_fp32_row_bytes: u64,
    pub wire_fp16_row_bytes: u64,
    pub wire_tag_bytes: u64,
    /// Final hot/cold row census across ranks and merge groups (zero in
    /// fp32 mode) plus cumulative cold-row quantization write-backs.
    pub hot_rows: u64,
    pub cold_rows: u64,
    pub quantize_ops: u64,
    /// Effective value-storage bytes under the active policy (hot rows
    /// 4 B, cold rows 2 B per element, summed over groups); equals
    /// `table_rows × dim × 4` accounting in fp32 mode.
    pub effective_value_bytes: u64,
}

impl TrainReport {
    pub fn mean_sim_step(&self) -> f64 {
        let n = self.steps.len().max(1) as f64;
        self.steps.iter().map(|s| s.sim_step_s).sum::<f64>() / n
    }

    /// Mean exposed communication seconds per step (across workers).
    pub fn mean_exposed_comm_s(&self) -> f64 {
        let per_step: Vec<f64> = self
            .steps
            .iter()
            .map(|s| slice_mean(&s.sim_exposed_comm_s))
            .collect();
        slice_mean(&per_step)
    }

    /// Mean ID-exchange seconds per step hidden behind compute.
    pub fn mean_hidden_comm_s(&self) -> f64 {
        let per_step: Vec<f64> = self
            .steps
            .iter()
            .map(|s| slice_mean(&s.sim_hidden_comm_s))
            .collect();
        slice_mean(&per_step)
    }

    /// Mean embedding-reply seconds per step hidden by double-buffering.
    pub fn mean_hidden_reply_s(&self) -> f64 {
        let per_step: Vec<f64> = self
            .steps
            .iter()
            .map(|s| slice_mean(&s.sim_hidden_reply_s))
            .collect();
        slice_mean(&per_step)
    }

    /// Mean backward-gradient seconds per step hidden behind the next
    /// micro-batch's forward.
    pub fn mean_hidden_grad_s(&self) -> f64 {
        let per_step: Vec<f64> = self
            .steps
            .iter()
            .map(|s| slice_mean(&s.sim_hidden_grad_s))
            .collect();
        slice_mean(&per_step)
    }

    /// Mean ID-exchange seconds per step hidden behind the previous
    /// step's dense sync (cross-step pipelining).
    pub fn mean_hidden_boundary_s(&self) -> f64 {
        let per_step: Vec<f64> = self
            .steps
            .iter()
            .map(|s| slice_mean(&s.sim_hidden_boundary_s))
            .collect();
        slice_mean(&per_step)
    }

    /// Mean last-round gradient-push seconds per step hidden behind the
    /// dense sync (the cross-step gradient lane).
    pub fn mean_hidden_boundary_grad_s(&self) -> f64 {
        let per_step: Vec<f64> = self
            .steps
            .iter()
            .map(|s| slice_mean(&s.sim_hidden_boundary_grad_s))
            .collect();
        slice_mean(&per_step)
    }

    pub fn final_losses(&self) -> (f64, f64) {
        let tail = self.steps.len().saturating_sub(5);
        let w = &self.steps[tail..];
        let n = w.len().max(1) as f64;
        (
            w.iter().map(|s| s.loss_ctr).sum::<f64>() / n,
            w.iter().map(|s| s.loss_ctcvr).sum::<f64>() / n,
        )
    }
}

/// Mean of a slice (0.0 when empty).
fn slice_mean(xs: &[f64]) -> f64 {
    xs.iter().sum::<f64>() / xs.len().max(1) as f64
}

/// Rolling-tail cap on per-step records for endless online runs
/// (`--intervals 0`): once the log reaches twice this, the oldest half
/// is dropped, bounding memory at O(cap) with amortized O(1) cost.
const ENDLESS_RECORD_CAP: usize = 1 << 16;

/// The coordinator.
pub struct Trainer {
    pub opts: TrainerOptions,
    engine: Engine,
    model_cfg: ModelConfig,
}

impl Trainer {
    pub fn new(mut opts: TrainerOptions, engine: Engine) -> Result<Trainer> {
        // Scenario presets fill online defaults (day cadence, default
        // admission, soak TTL) before validation, so programmatic and
        // CLI runs agree on the effective options. Idempotent.
        if let Some(sc) = opts.scenario.clone() {
            if let Some(o) = opts.online.as_mut() {
                sc.apply_online_defaults(o);
            }
        }
        opts.validate()?;
        let model_cfg = ModelConfig::by_name(&opts.model)
            .with_context(|| format!("unknown model preset `{}`", opts.model))?;
        // Real execution requires the sparse dim to match the model dim.
        anyhow::ensure!(
            model_cfg.dim_factor == 1,
            "real training runs require dim_factor == 1 (use sim mode)"
        );
        let arts = engine.manifest().model(&opts.model)?;
        // Resolve the schema here so an unknown preset fails in
        // Trainer::new rather than inside a worker thread. Presets are
        // constructed *at* the model dim (context dims clamp to it), so
        // no feature can be wider than the token embedding it pools
        // into.
        Schema::by_name(opts.effective_schema(), arts.emb_dim)?;
        Ok(Trainer {
            opts,
            engine,
            model_cfg,
        })
    }

    /// Run the synchronous training loop; blocks until done.
    pub fn run(&self) -> Result<TrainReport> {
        let world = self.opts.cluster.world;
        let handles = CommGroup::new(world);
        let opts = Arc::new(self.opts.clone());
        let cfg = Arc::new(self.model_cfg.clone());
        let engine = self.engine.clone();

        // ONE worker pool for the whole training process, sized from
        // `--threads` (0 = machine). Each worker receives a
        // deterministic fair-share view (`⌈threads/world⌉`) onto the
        // same threads, so `world` concurrent parallel regions split
        // the pool instead of oversubscribing the host.
        let pool = WorkerPool::new(WorkerPool::resolve_threads(self.opts.threads));

        let mut joins = Vec::new();
        for (rank, comm) in handles.into_iter().enumerate() {
            let opts = Arc::clone(&opts);
            let cfg = Arc::clone(&cfg);
            let engine = engine.clone();
            let pool = Arc::new(pool.fair_share(world));
            joins.push(
                std::thread::Builder::new()
                    .name(format!("worker-{rank}"))
                    .spawn(move || worker_main(rank, comm, opts, cfg, engine, pool))
                    .context("spawn worker")?,
            );
        }
        let mut outputs = Vec::new();
        for j in joins {
            outputs.push(j.join().expect("worker panicked")?);
        }
        Ok(report_from_outputs(outputs))
    }

    /// Run exactly ONE rank of a multi-process group in this process,
    /// over the given (remote-backed) communicator; blocks until done.
    /// The returned report carries only this rank's shard state
    /// (`group_checksums`, `group_rows`, ...) — the supervisor sums
    /// them across rank reports. Step records are collective values and
    /// identical on every rank.
    ///
    /// The worker pool is sized from `--threads` directly — NOT the
    /// single-process fair share `⌈threads/world⌉` — because this
    /// process hosts one rank and owns the whole machine share the
    /// launcher gave it. Results are bit-identical for every pool size,
    /// so the two paths still agree bitwise.
    pub fn run_rank(&self, comm: CommHandle) -> Result<TrainReport> {
        let rank = comm.rank;
        let opts = Arc::new(self.opts.clone());
        let cfg = Arc::new(self.model_cfg.clone());
        let pool = Arc::new(WorkerPool::new(WorkerPool::resolve_threads(
            self.opts.threads,
        )));
        let out = worker_main(rank, comm, opts, cfg, self.engine.clone(), pool)?;
        Ok(report_from_outputs(vec![out]))
    }
}

/// Merge worker-local results into the run report. The lowest-rank
/// output carries the step records (they are collective values,
/// identical on every worker; rank 0 wins in a full group, and a
/// single-rank group — [`Trainer::run_rank`] — contributes its own).
fn report_from_outputs(outputs: Vec<WorkerOutput>) -> TrainReport {
    let mut gauc_ctr = GaucAccumulator::new();
    let mut gauc_ctcvr = GaucAccumulator::new();
    let mut phases = PhaseTimer::new();
    let mut table_rows = 0;
    let mut table_memory = 0;
    let mut volume = DedupVolume::default();
    let mut truncated = 0;
    let mut steps = Vec::new();
    let mut wall = Throughput::default();
    let mut steps_rank: Option<usize> = None;
    let mut prefetch_occ = 0.0;
    let mut checksum = 0u64;
    let mut transport_retries = 0u64;
    let mut table_stats = TableStats::default();
    let mut group_dims: Vec<usize> = Vec::new();
    let mut group_volumes: Vec<DedupVolume> = Vec::new();
    let mut group_checksums: Vec<u64> = Vec::new();
    let mut group_rows: Vec<usize> = Vec::new();
    let mut scenario: Option<String> = None;
    let mut fill_denom = 0u64;
    let mut precision = String::new();
    let mut precision_stats = crate::embedding::precision::PrecisionStats::default();
    let mut effective_value_bytes = 0u64;
    let n_workers = outputs.len().max(1) as f64;
    for out in outputs {
        precision_stats.merge(&out.precision_stats);
        effective_value_bytes += out.effective_value_bytes;
        table_stats.merge(&out.table_stats);
        gauc_ctr.merge(out.gauc_ctr);
        gauc_ctcvr.merge(out.gauc_ctcvr);
        phases.merge(&out.phases);
        table_rows += out.table_rows;
        table_memory += out.table_memory;
        prefetch_occ += out.prefetch_occupancy / n_workers;
        checksum = checksum.wrapping_add(out.table_checksum);
        transport_retries += out.transport_retries;
        volume.merge(&out.volume);
        truncated += out.truncated;
        // Per-group aggregates: every worker carries the same group
        // structure (same schema, same plan).
        if group_dims.is_empty() {
            group_dims = out.group_dims.clone();
            group_volumes = vec![DedupVolume::default(); group_dims.len()];
            group_checksums = vec![0; group_dims.len()];
            group_rows = vec![0; group_dims.len()];
        }
        for (g, v) in out.group_volumes.iter().enumerate() {
            group_volumes[g].merge(v);
        }
        for (g, &c) in out.group_checksums.iter().enumerate() {
            group_checksums[g] = group_checksums[g].wrapping_add(c);
        }
        for (g, &r) in out.group_rows.iter().enumerate() {
            group_rows[g] += r;
        }
        let lowest_so_far = match steps_rank {
            None => true,
            Some(r) => out.rank < r,
        };
        if lowest_so_far {
            steps_rank = Some(out.rank);
            steps = out.steps;
            wall = out.wall;
            scenario = out.scenario.clone();
            fill_denom = out.fill_denom;
            precision = out.precision.clone();
        }
    }
    let sim_total: f64 = steps.iter().map(|s| s.sim_step_s).sum();
    let total_samples: u64 = steps.iter().map(|s| s.samples).sum();
    let total_tokens: u64 = steps.iter().map(|s| s.tokens.iter().sum::<u64>()).sum();
    // Online counters are already globally summed per interval
    // (collective gathers at the boundary); totalling rank 0's step
    // records yields the run totals.
    let online_admitted: u64 = steps.iter().map(|s| s.online_admitted).sum();
    let online_rejected: u64 = steps.iter().map(|s| s.online_rejected).sum();
    let online_expired: u64 = steps.iter().map(|s| s.online_expired).sum();
    let online_synced_rows: u64 = steps.iter().map(|s| s.online_synced_rows).sum();
    let online_sync_bytes: u64 = steps.iter().map(|s| s.online_sync_bytes).sum();
    let lookup_ops_merged: u64 = steps.iter().map(|s| s.lookup_ops_merged).sum();
    let lookup_ops_unmerged: u64 = steps.iter().map(|s| s.lookup_ops_unmerged).sum();
    // Wire meters are already globally summed per step (collective
    // gathers at the step boundary), like the online counters.
    let mut wire_payload_bytes = vec![0u64; LANES];
    let mut wire_header_bytes = 0u64;
    let mut wire_fp32_row_bytes = 0u64;
    let mut wire_fp16_row_bytes = 0u64;
    let mut wire_tag_bytes = 0u64;
    for s in &steps {
        for (l, &b) in s.wire_payload_bytes.iter().enumerate() {
            wire_payload_bytes[l] += b;
        }
        wire_header_bytes += s.wire_header_bytes;
        wire_fp32_row_bytes += s.wire_fp32_row_bytes;
        wire_fp16_row_bytes += s.wire_fp16_row_bytes;
        wire_tag_bytes += s.wire_tag_bytes;
    }
    // The final census comes from the last step's (already gathered)
    // snapshot; the quantize-op total merges across workers.
    let hot_rows = steps.last().map(|s| s.hot_rows).unwrap_or(0);
    let cold_rows = steps.last().map(|s| s.cold_rows).unwrap_or(0);
    // Scenario telemetry roll-ups over the (already globally summed)
    // per-step meters.
    let n_steps = steps.len().max(1) as f64;
    let peak_resident_rows = steps.iter().map(|s| s.resident_rows).max().unwrap_or(0);
    let total_evictions: u64 = steps.iter().map(|s| s.evictions).sum();
    let batcher_carryover_mean =
        steps.iter().map(|s| s.batcher_carryover as f64).sum::<f64>() / n_steps;
    let batcher_fill_mean = if fill_denom > 0 {
        steps
            .iter()
            .map(|s| s.tokens.iter().sum::<u64>() as f64 / fill_denom as f64)
            .sum::<f64>()
            / n_steps
    } else {
        0.0
    };
    TrainReport {
        table_stats,
        group_dims,
        group_volumes,
        group_checksums,
        group_rows,
        lookup_ops_merged,
        lookup_ops_unmerged,
        online_admitted,
        online_rejected,
        online_expired,
        online_synced_rows,
        online_sync_bytes,
        wire_payload_bytes,
        wire_header_bytes,
        dist: DistStats {
            transport_retries,
            ..DistStats::default()
        },
        scenario,
        peak_resident_rows,
        batcher_carryover_mean,
        batcher_fill_mean,
        total_evictions,
        precision,
        wire_fp32_row_bytes,
        wire_fp16_row_bytes,
        wire_tag_bytes,
        hot_rows,
        cold_rows,
        quantize_ops: precision_stats.quantize_ops,
        effective_value_bytes,
        gauc_ctr: gauc_ctr.gauc(),
        gauc_ctcvr: gauc_ctcvr.gauc(),
        phases,
        wall,
        sim_samples_per_sec: total_samples as f64 / sim_total.max(1e-12),
        sim_tokens_per_sec: total_tokens as f64 / sim_total.max(1e-12),
        table_rows,
        table_memory_bytes: table_memory,
        dedup_volume: volume,
        truncated_sequences: truncated,
        prefetch_occupancy: prefetch_occ,
        embedding_checksum: checksum,
        steps,
    }
}

/// Worker-local results returned to the coordinator.
struct WorkerOutput {
    rank: usize,
    steps: Vec<StepRecord>,
    gauc_ctr: GaucAccumulator,
    gauc_ctcvr: GaucAccumulator,
    phases: PhaseTimer,
    wall: Throughput,
    table_rows: usize,
    table_memory: usize,
    volume: DedupVolume,
    truncated: u64,
    prefetch_occupancy: f64,
    table_checksum: u64,
    table_stats: TableStats,
    group_dims: Vec<usize>,
    group_volumes: Vec<DedupVolume>,
    group_checksums: Vec<u64>,
    group_rows: Vec<usize>,
    /// Transport-level send retries that eventually succeeded (0 for
    /// the in-process channel backend).
    transport_retries: u64,
    /// Scenario name the run trained under (report labeling).
    scenario: Option<String>,
    /// `target_tokens × world` when the dynamic batcher is on (the
    /// denominator of the report's fill metric); 0 otherwise.
    fill_denom: u64,
    /// The precision mode string (report labeling).
    precision: String,
    /// Final hot/cold census + quantization ops across this worker's
    /// merge groups (zero counts in fp32 mode).
    precision_stats: crate::embedding::precision::PrecisionStats,
    /// Effective value-storage bytes across this worker's groups under
    /// the active policy.
    effective_value_bytes: u64,
}

/// One micro-batch prepared for the engine.
struct Micro {
    batch: Batch,
    bucket: (usize, usize),
}

/// One step's locally prepared inputs: the balanced batch split into
/// micro-batches plus their occurrence streams. Prepared one step ahead
/// so cross-step pipelining can post step *s+1*'s first ID all-to-all
/// during step *s*'s dense sync.
struct StepData {
    tokens: u64,
    samples: u64,
    flops: f64,
    micros: Vec<Micro>,
    round_ids: Vec<(BatchIds, (usize, usize))>,
    /// Tokens the batcher held back after cutting this batch.
    carryover: u64,
    /// Generator day the batch was drawn from (scenario telemetry +
    /// the admission sketch's day-decay trigger).
    day: u64,
}

/// Persistent per-worker scratch arenas for the dense step's inputs and
/// the exchange buffers — reused every micro-batch so the hot loop does
/// no per-step allocation (the engine's [`TrainScratch`] covers the
/// outputs).
#[derive(Default)]
struct WorkerArena {
    emb: Vec<f32>,
    lengths: Vec<i32>,
    labels: Vec<f32>,
    /// One occurrence-gradient buffer per merge group.
    occ_grads: Vec<Vec<f32>>,
}

fn worker_main(
    rank: usize,
    mut comm: CommHandle,
    opts: Arc<TrainerOptions>,
    cfg: Arc<ModelConfig>,
    engine: Engine,
    pool: Arc<WorkerPool>,
) -> Result<WorkerOutput> {
    let world = comm.world;
    let arts = engine.manifest().model(&opts.model)?.clone();
    let dir = engine.manifest().dir.clone();
    let d = arts.emb_dim;
    let schema = Schema::by_name(opts.effective_schema(), d)?;
    // §4.2 table merging unless ablated away (`--no-merging` keeps one
    // group per logical table, so every round pays one exchange per
    // table instead of one per merge group).
    let plan = if opts.table_merging {
        MergePlan::build(&schema.all_features())
    } else {
        MergePlan::build_unmerged(&schema.all_features())
    };
    let n_groups = plan.num_groups();

    // Per-worker data shard: independent generator stream feeding a
    // background prefetcher (the paper's copy stream) so chunk
    // generation overlaps training; the bounded queue's occupancy is
    // surfaced in the report. The channel preserves stream order, so
    // determinism is untouched. Online mode additionally advances the
    // generator's day every `day_every` chunks, so fresh IDs keep
    // arriving (the admission/TTL workload); offline keeps
    // `day_every = 0`, which reproduces the plain generator stream.
    let mut gen_cfg = opts.generator.clone();
    // Scenario presets reshape the stream's *distribution* before the
    // per-rank seed mixing; the seed itself is never touched, so the
    // familiar seed → shard mapping is preserved under every scenario.
    if let Some(sc) = &opts.scenario {
        sc.shape_generator(&mut gen_cfg);
    }
    gen_cfg.seed = opts.generator.seed ^ (rank as u64).wrapping_mul(0x9E37_79B9);
    // Cap lengths at the largest bucket so nothing needs truncation.
    let max_l = arts.largest_bucket().len;
    gen_cfg.max_len = gen_cfg.max_len.min(max_l);
    let day_every = opts.online.as_ref().map_or(0, |o| o.day_every);
    let mut stream = StreamingSource::spawn(
        gen_cfg,
        schema.clone(),
        32,
        opts.prefetch_depth.max(1),
        day_every,
    );

    // Batcher per the ablation toggle.
    let mut batcher: Box<dyn Batcher> = if opts.train.sequence_balancing {
        Box::new(DynamicBatcher::new(opts.train.target_tokens))
    } else {
        Box::new(FixedBatcher::new(opts.train.fixed_batch))
    };

    // `pool` is this worker's fair-share view onto the process-global
    // pool: dense forward/backward chunking, dedup, stage-2 serve
    // fan-out, row expansion, gradient aggregation and both optimizers
    // all ride it. Results are bit-identical for every pool size.

    // Sparse side: **one merged lock-striped shard table per merge
    // group** (the §4.2 fusion made physical — a homogeneous schema has
    // exactly one group and reproduces the historical single-table path
    // byte for byte). The stripe count is fixed (8) independent of
    // `threads`, so per-stripe state — and thus the checksum — cannot
    // depend on the pool size. Each group's table sits behind its own
    // online gate (pure passthrough offline; serial
    // admission/touch/delta pre-pass online — the online knobs apply
    // uniformly to every group) and its own sharded exchange. All
    // per-group collectives run in ascending group order on every rank,
    // so the FIFO comm lanes stay aligned.
    let mut sharded: Vec<ShardedEmbedding<OnlineTable>> = plan
        .groups
        .iter()
        .map(|g| {
            let mut tcfg = DynamicTableConfig::new(g.dim)
                .with_capacity(opts.shard_capacity)
                .with_seed(engine.manifest().seed ^ 0xEB);
            // Scenario capacity pressure: a per-group resident-row
            // budget (multi-tenant preset). Offline-only — validate()
            // guarantees budgeted scenarios never run online, so the
            // gate below is always the passthrough (OnlineTable::online
            // refuses budgeted tables).
            if let Some(b) = opts.scenario.as_ref().and_then(|s| s.row_budget) {
                tcfg = tcfg.with_max_rows(b);
            }
            // The precision policy composes under the online gate: the
            // concurrent table owns classification + storage
            // quantization, the gate forwards discovery, and the
            // exchange keys its wire compression off the policy.
            let table =
                ConcurrentDynamicTable::new(tcfg, 8).with_precision(opts.precision_policy());
            let gate = match &opts.online {
                Some(o) => OnlineTable::online(
                    table,
                    o.admission.clone().map(FeatureAdmission::new),
                ),
                None => OnlineTable::passthrough(table),
            };
            ShardedEmbedding::new(gate, opts.train.dedup).with_pool(Arc::clone(&pool))
        })
        .collect();
    // The multiplexed exchange front-end: packs every group's payload
    // into one message per comm lane (§3.3 raw-speed pass). Falls back
    // to the per-group schedule when disabled or single-group, where it
    // is wire-identical by construction.
    let mut exchange = GroupExchange::new(opts.multiplex_exchange);
    let adam_params = AdamParams {
        lr: opts.train.lr,
        beta1: opts.train.beta1,
        beta2: opts.train.beta2,
        eps: opts.train.eps,
    };
    let mut sparse_opt: Vec<SparseAdam> = plan
        .groups
        .iter()
        .map(|g| SparseAdam::new(g.dim, adam_params))
        .collect();
    let mut sparse_acc: Vec<SparseAccumulator> = plan
        .groups
        .iter()
        .map(|g| SparseAccumulator::new(g.dim))
        .collect();

    // Dense replica + optimizer (identical init on every worker).
    let mut params = arts.load_params(&dir)?;
    let mut dense_opt = DenseAdam::new(
        params.len(),
        AdamParams {
            lr: opts.train.lr,
            beta1: opts.train.beta1,
            beta2: opts.train.beta2,
            eps: opts.train.eps,
        },
    );
    let mut dense_acc = DenseAccumulator::new(params.len());

    // Online runs are bounded by `intervals × sync_interval` (`None` =
    // run until interrupted); offline runs by `steps`.
    let total_steps: Option<usize> = match &opts.online {
        None => Some(opts.steps),
        Some(o) => o.total_steps(),
    };
    let online_mode = opts.online.is_some();
    // GAUC accumulates every sample's (score, label) per user; on an
    // endless run that grows without bound AND the report it would feed
    // is unreachable (the run only ends by interruption) — so endless
    // runs never accumulate it.
    let collect_gauc = opts.collect_gauc && total_steps.is_some();

    let mut phases = PhaseTimer::new();
    let mut gauc_ctr = GaucAccumulator::new();
    let mut gauc_ctcvr = GaucAccumulator::new();
    let mut records = Vec::with_capacity(total_steps.unwrap_or(0).clamp(16, 1 << 16));
    let mut wall = Throughput::default();
    let truncated = 0u64;
    let mut vol_prev: Vec<DedupVolume> = vec![DedupVolume::default(); n_groups];
    let mut scratch = TrainScratch::new();
    let mut arena = WorkerArena::default();

    // Cross-step pipelining posts step s+1's first ID all-to-all during
    // step s's dense sync; it needs the next step's occurrence stream
    // early, so step data is always prepared one step ahead.
    let cross = opts.overlap && opts.cross_step;
    // Simulated dense all-reduce (the boundary window the cross-step
    // exchange hides behind); constant across steps.
    let t_allreduce = opts.net.all_reduce_time(world, params.len() * 4);
    // Occurrence stream of an empty micro-batch (alignment rounds).
    let empty_ids = BatchIds::build(
        &Batch {
            sequences: vec![],
            tokens: 0,
        },
        &schema,
        &plan,
    );

    // The newest generator day observed on this rank's stream (chunks
    // carry their day stamp; the batcher erases it, so it is captured
    // at pull time). Read back per step for the scenario telemetry and
    // the admission sketch's day-decay trigger.
    let day_seen = std::cell::Cell::new(0u64);

    // Prepare one step's local inputs: pull a balanced batch, split it
    // into micro-batches and build their occurrence streams.
    let mut prepare = |phases: &mut PhaseTimer| -> StepData {
        let batch = phases.time("1_data", || loop {
            if let Some(b) = batcher.next_batch() {
                break b;
            }
            let chunk = stream.next_chunk();
            day_seen.set(day_seen.get().max(chunk.day));
            batcher.push_chunk(chunk.sequences);
        });
        let carryover = batcher.queued_tokens() as u64;
        let day = day_seen.get();
        let tokens = batch.tokens as u64;
        let samples = batch.sequences.len() as u64;
        // Simulated compute cost from REAL per-sequence lengths (the
        // GPU's actual workload; padding is skipped by the fused
        // kernel's masked tiles).
        let flops: f64 = batch
            .sequences
            .iter()
            .map(|s| cfg.forward_flops(s.len()))
            .sum();
        let micros = split_micros(batch, &arts);
        // Occurrence streams for every local micro up front, so round
        // k+1's ID exchange can be posted while round k computes — and
        // the first stream exists before the previous step's dense sync
        // (cross-step mode).
        let round_ids: Vec<(BatchIds, (usize, usize))> = phases.time("2_lookup", || {
            micros
                .iter()
                .map(|m| {
                    (
                        BatchIds::build_pooled(&m.batch, &schema, &plan, Some(pool.as_ref())),
                        m.bucket,
                    )
                })
                .collect()
        });
        StepData {
            tokens,
            samples,
            flops,
            micros,
            round_ids,
            carryover,
            day,
        }
    };

    // Step data prepared one step ahead (None only before step 0, so
    // the first step's data wait lands inside its own wall window).
    let mut next_data: Option<StepData> = None;
    // Admission totals at the previous interval boundary (the deltas
    // are what each interval reports).
    let mut prev_admitted = 0u64;
    let mut prev_rejected = 0u64;
    // Carried across the step boundary in cross-step mode: step s+1's
    // first posted ID exchange (all merge groups' lanes in one handle).
    let mut posted: Option<MultiLookup> = None;

    // ---- multi-process resume (dist mode) --------------------------
    // Recovery replays the delta chain: deltas carry FULL rows (values
    // + Adam m/v/t), and dist mode disallows TTL/admission, so every
    // row resident at step R×sync_interval appears in some delta ≤ R.
    // Installing deltas 1..=R into the empty tables plus delta R's
    // dense state reproduces the uninterrupted state bit for bit. The
    // data stream is then fast-forwarded past the covered steps (one
    // discarded `prepare` per step — the loop consumes exactly one per
    // step), so the first live step sees exactly the batch it would
    // have in the uninterrupted run.
    let dist_hooks = opts.dist.as_ref().and_then(|dc| dc.hooks.clone());
    let resume_seq = opts.dist.as_ref().map_or(0, |dc| dc.resume_seq);
    let start_step = if resume_seq > 0 {
        let ocfg = opts.online.as_ref().expect("validate: dist requires online");
        let sdir = ocfg
            .sync_dir
            .as_ref()
            .expect("validate: dist requires --sync-dir");
        for seq in 1..=resume_seq {
            let meta = crate::checkpoint::delta::load_delta_meta(sdir, seq)
                .with_context(|| format!("resume: delta {seq} meta"))?;
            anyhow::ensure!(
                meta.world == world,
                "resume: delta {seq} was written for world {} (this run is world {world})",
                meta.world
            );
            // Replaying a mixed-precision chain under different flags
            // would silently reconstruct cold rows on the wrong grid;
            // the snapshot's recorded policy must match this run's.
            let dprec = crate::checkpoint::delta::load_delta_precision_policy(sdir, seq)
                .with_context(|| format!("resume: delta {seq} precision meta"))?;
            anyhow::ensure!(
                dprec == opts.precision_policy(),
                "resume: delta {seq} was written under {dprec:?} but this run uses \
                 {:?} (--precision/--hot-threshold must match the chain)",
                opts.precision_policy()
            );
            for g in 0..n_groups {
                let (rows, removed) =
                    crate::checkpoint::delta::load_delta_shard_group(sdir, &meta, rank, g)
                        .with_context(|| {
                            format!("resume: delta {seq} rank {rank} group {g}")
                        })?;
                crate::checkpoint::delta::apply_delta(
                    sharded[g].table().inner(),
                    &mut sparse_opt[g],
                    rows,
                    &removed,
                );
            }
        }
        let (restored, opt_state) = crate::checkpoint::load_dense(
            &crate::checkpoint::delta::delta_dir(sdir, resume_seq),
            params.len(),
        )
        .with_context(|| format!("resume: delta {resume_seq} dense state"))?;
        params = restored;
        dense_opt.restore_state(&opt_state)?;
        let start = resume_seq as usize * ocfg.sync_interval;
        for _ in 0..start {
            let _ = prepare(&mut phases);
        }
        start
    } else {
        0
    };
    // Per-rank wire meters at the previous step boundary: payload bytes
    // per lane minus the multiplexed packing headers, so the records
    // can assert payload conservation against the per-group schedule.
    let mut wire_prev = comm.stats.lane_bytes;
    let mut hdr_prev = [0u64; LANES];
    // Scenario telemetry state: the last generator day whose boundary
    // was already applied to the admission sketches, and the eviction
    // total at the previous step boundary (per-step deltas are what
    // the records carry).
    let mut last_day = 0u64;
    let mut evict_prev = 0u64;
    // Mixed-precision wire meter at the previous step boundary (stays
    // default-zero in fp32 mode, where the meters never move).
    let mut pwire_prev = crate::embedding::sharded::PrecisionWireBytes::default();

    let mut step = start_step;
    loop {
        if let Some(total) = total_steps {
            if step >= total {
                break;
            }
        }
        let step_t0 = std::time::Instant::now();
        // The TTL clock: every touch/admission decision this step is
        // stamped with it (no-op for the passthrough gates).
        for se in sharded.iter_mut() {
            se.table_mut().set_step(step as u64);
        }
        // Heartbeat step stamp / kill-fault injection point: before the
        // first collective of the step, so an injected crash never
        // leaves peers blocked mid-exchange pattern.
        if let Some(h) = &dist_hooks {
            h.on_step(step);
        }
        let data = match next_data.take() {
            Some(d) => d,
            None => prepare(&mut phases),
        };
        let my_tokens = data.tokens;
        let my_samples = data.samples;
        let my_flops = data.flops;
        let my_carryover = data.carryover;
        let my_day = data.day;
        // Day boundary: advance the admission sketches once per crossed
        // generator day (count-min day decay + hysteresis bookkeeping).
        // Purely rank-local state — the per-rank stream's day stamps are
        // deterministic, so this never perturbs cross-thread identity.
        while last_day < my_day {
            last_day += 1;
            for se in sharded.iter_mut() {
                se.table_mut().advance_day();
            }
        }

        // Collective alignment: every worker runs the same number of
        // micro rounds (empty rounds keep the all-to-alls matched).
        // Every rank has ≥ 1 micro, so round 0 — the one cross-step
        // pipelining posts early — always exists on every rank.
        let n_micro = comm.all_gather_u64(data.micros.len() as u64);
        let rounds = *n_micro.iter().max().unwrap() as usize;

        let mut step_loss = [0.0f64; 2];
        let mut posted_bwd: Option<MultiBackward> = None;
        for round in 0..rounds {
            let micro = data.micros.get(round);
            let (bi, bucket): (&BatchIds, (usize, usize)) = match data.round_ids.get(round) {
                Some(p) => (&p.0, p.1),
                None => (&empty_ids, (0, 0)),
            };

            // ---- lookup (collective, three-phase, multiplexed) --------
            // With overlap on, this round's IDs were already posted
            // during the previous round (or, for round 0 in cross-step
            // mode, during the previous step's dense sync); serve the
            // shards now and post the embedding replies...
            let pending: MultiLookup = match posted.take() {
                Some(p) => p,
                None => phases.time("2_lookup", || {
                    let ids: Vec<&[crate::embedding::GlobalId]> =
                        (0..n_groups).map(|g| bi.groups[g].ids.as_slice()).collect();
                    exchange.post_ids(&mut comm, &mut sharded, &ids)
                }),
            };
            let served = phases.time("2_lookup", || {
                exchange.serve_reply(&mut comm, &mut sharded, pending, true)
            });
            if opts.overlap && round + 1 < rounds {
                // ...then post the next round's ID all-to-all while
                // this round's replies are still on the wire — the
                // double-buffered round: both exchanges in flight at
                // once, each on its own comm lane (multiplexed mode
                // packs all groups into one message per lane; per-group
                // mode keeps the lanes FIFO in group order).
                posted = Some(phases.time("2_lookup", || {
                    let next_ids: Vec<&[crate::embedding::GlobalId]> = (0..n_groups)
                        .map(|g| {
                            data.round_ids
                                .get(round + 1)
                                .map(|p| p.0.groups[g].ids.as_slice())
                                .unwrap_or(&[])
                        })
                        .collect();
                    exchange.post_ids(&mut comm, &mut sharded, &next_ids)
                }));
            }
            let rows: Vec<Vec<f32>> = phases.time("2_lookup", || {
                exchange.complete_reply(&mut comm, &mut sharded, served)
            });

            // ---- forward + backward (local, pool-parallel) ------------
            let have_grads = if let Some(m) = micro {
                let (bb, bl) = bucket;
                phases.time("3_compute", || -> Result<()> {
                    bi.pool_into(&rows, d, bb, bl, Some(pool.as_ref()), &mut arena.emb);
                    arena.lengths.clear();
                    arena.lengths.resize(bb, 0);
                    arena.labels.clear();
                    arena.labels.resize(bb * arts.tasks, 0.0);
                    for (i, s) in m.batch.sequences.iter().enumerate() {
                        arena.lengths[i] = s.len() as i32;
                        arena.labels[i * arts.tasks] = s.labels[0];
                        arena.labels[i * arts.tasks + 1] = s.labels[1];
                    }
                    // The reference backend executes inline with the
                    // batch chunked across the shared pool; outputs land
                    // in the reusable scratch arena.
                    engine.train_step_into(
                        &opts.model,
                        bucket,
                        &params,
                        &arena.emb,
                        &arena.lengths,
                        &arena.labels,
                        Some(pool.as_ref()),
                        &mut scratch,
                    )
                })?;
                step_loss[0] += scratch.loss_sums[0] as f64;
                step_loss[1] += scratch.loss_sums[1] as f64;
                dense_acc.add(&scratch.grads, scratch.n_valid as u64);
                if collect_gauc && step >= opts.gauc_warmup {
                    for (i, s) in m.batch.sequences.iter().enumerate() {
                        let z0 = scratch.logits[i * arts.tasks];
                        let z1 = scratch.logits[i * arts.tasks + 1];
                        gauc_ctr.add(s.user_id, z0, s.labels[0]);
                        gauc_ctcvr.add(s.user_id, z1, s.labels[1]);
                    }
                }
                bi.scatter_grad_into(&scratch.emb_grad, d, bb, bl, Some(pool.as_ref()), &mut arena.occ_grads);
                true
            } else {
                false
            };

            // ---- sparse backward (collective) + local accumulation ----
            // Complete the *previous* round's gradient exchange only
            // now — its wire time hid behind this round's forward and
            // backward compute. Then post this round's gradients; with
            // overlap on they stay in flight until the next round (or
            // the flush at the step boundary). Round order of
            // accumulation is identical to the blocking schedule, so
            // numerics match bitwise.
            phases.time("4_sparse_update", || {
                if let Some(pb) = posted_bwd.take() {
                    for (g, (lids, lgrads)) in exchange
                        .complete_backward(&mut comm, &mut sharded, pb)
                        .into_iter()
                        .enumerate()
                    {
                        sparse_acc[g].add(&lids, &lgrads, 0);
                    }
                }
                let ids: Vec<&[crate::embedding::GlobalId]> =
                    (0..n_groups).map(|g| bi.groups[g].ids.as_slice()).collect();
                let grads: Vec<&[f32]> = (0..n_groups)
                    .map(|g| {
                        if have_grads {
                            arena.occ_grads[g].as_slice()
                        } else {
                            &[][..]
                        }
                    })
                    .collect();
                let pb = exchange.post_backward(&mut comm, &mut sharded, &ids, &grads);
                if opts.overlap {
                    posted_bwd = Some(pb);
                } else {
                    for (g, (lids, lgrads)) in exchange
                        .complete_backward(&mut comm, &mut sharded, pb)
                        .into_iter()
                        .enumerate()
                    {
                        sparse_acc[g].add(&lids, &lgrads, 0);
                    }
                }
            });
        }
        // Flush the last round's in-flight gradient exchange before the
        // optimizer applies updates — unless cross-step mode keeps it in
        // flight across the dense all-reduce (the cross-step gradient
        // lane); the dense-sync block below drains it right before the
        // sparse optimizer reads the accumulators, so the accumulation
        // order — and every number — is unchanged.
        if !cross {
            phases.time("4_sparse_update", || {
                if let Some(pb) = posted_bwd.take() {
                    for (g, (lids, lgrads)) in exchange
                        .complete_backward(&mut comm, &mut sharded, pb)
                        .into_iter()
                        .enumerate()
                    {
                        sparse_acc[g].add(&lids, &lgrads, 0);
                    }
                }
            });
        }
        debug_assert!(posted.is_none(), "a posted lookup outlived its rounds");

        // Volume snapshot BEFORE the cross-step post, so each step's
        // deltas cover exactly its own rounds whether or not the next
        // step's first exchange is posted early.
        let dv: Vec<DedupVolume> = sharded.iter().map(|s| s.volume).collect();

        // ---- cross-step boundary -------------------------------------
        // Prepare step s+1 and (cross-step mode) post its first ID
        // all-to-all now, so the exchange's wire time rides the dense
        // all-reduce + optimizer apply below instead of the next step's
        // critical path. Posting order is identical on every rank, and
        // posting earlier cannot change any arithmetic — only when the
        // wire time is waited on.
        let has_next_step = match total_steps {
            Some(total) => step + 1 < total,
            None => true,
        };
        if has_next_step {
            let next = prepare(&mut phases);
            if cross {
                posted = Some(phases.time("2_lookup", || {
                    let first_ids: Vec<&[crate::embedding::GlobalId]> = (0..n_groups)
                        .map(|g| {
                            next.round_ids
                                .first()
                                .map(|p| p.0.groups[g].ids.as_slice())
                                .unwrap_or(&[])
                        })
                        .collect();
                    exchange.post_ids(&mut comm, &mut sharded, &first_ids)
                }));
            }
            next_data = Some(next);
        }

        // ---- weighted dense sync + updates (collective) ---------------
        phases.time("5_dense_sync", || {
            let sizes = comm.all_gather_u64(my_samples);
            let total: u64 = sizes.iter().sum();
            let scale = 1.0 / total.max(1) as f32;
            let apply_now = (step + 1) % opts.train.grad_accum == 0;
            if apply_now {
                let (mut grads, _n) = dense_acc.take();
                comm.all_reduce_sum(&mut grads);
                // Dense Adam chunks elements across the pool; sparse
                // row-wise Adam fans unique rows out. Both are
                // bit-identical to their serial steps for every pool
                // size (disjoint elements / rows). Sparse state applies
                // group by group (disjoint id spaces).
                dense_opt.step_pooled(&mut params, &grads, scale, Some(pool.as_ref()));
            }
            // Cross-step gradient lane: the last round's gradient push
            // stayed in flight across the dense all-reduce above; drain
            // it now, before the sparse optimizer reads the
            // accumulators. No-op when cross-step mode is off (the
            // post-round-loop flush already ran) — and the accumulation
            // always lands before any sparse read, so the per-step
            // accumulation order is identical either way.
            if let Some(pb) = posted_bwd.take() {
                for (g, (lids, lgrads)) in exchange
                    .complete_backward(&mut comm, &mut sharded, pb)
                    .into_iter()
                    .enumerate()
                {
                    sparse_acc[g].add(&lids, &lgrads, 0);
                }
            }
            if apply_now {
                for g in 0..n_groups {
                    let (sids, sgrads, _) = sparse_acc[g].take();
                    // Online mode: gradients may target rows that
                    // admission rejected or the TTL sweeper retired —
                    // drop them before the optimizer so no phantom Adam
                    // state accumulates (serial pass; identical for
                    // every pool size).
                    let (sids, sgrads) = if online_mode {
                        filter_present(
                            sharded[g].table().inner(),
                            sids,
                            sgrads,
                            plan.groups[g].dim,
                        )
                    } else {
                        (sids, sgrads)
                    };
                    sparse_opt[g].step_concurrent(&pool, sharded[g].table(), &sids, &sgrads, scale);
                    // The concurrent optimizer writes through the shared
                    // delegation; record the touched rows for TTL +
                    // delta tracking (no-op for the passthrough gate).
                    sharded[g].table_mut().mark_updated(&sids);
                }
            }
        });

        // ---- online interval boundary ---------------------------------
        // Every `sync_interval` steps: TTL-sweep stale rows, drain the
        // delta tracker into an incremental snapshot (rows touched since
        // the last sync + retired ids) and account the sync volume. The
        // boundary falls on the same step on every rank, so the
        // collective gathers below stay aligned.
        let mut online_counts = [0u64; 5];
        let mut my_sync_s = 0.0f64;
        if let Some(ocfg) = &opts.online {
            if (step + 1) % ocfg.sync_interval == 0 {
                let seq = ((step + 1) / ocfg.sync_interval) as u64;
                // Per-group sweep + delta drain: the TTL and sync
                // cadence apply uniformly to every group, in group
                // order (deterministic).
                let (expired, group_payload) = phases.time("6_online_sync", || {
                    let mut expired = 0u64;
                    let mut payload: Vec<(Vec<GlobalId>, Vec<GlobalId>)> =
                        Vec::with_capacity(n_groups);
                    for g in 0..n_groups {
                        expired += sharded[g]
                            .table_mut()
                            .sweep_expired(ocfg.feature_ttl, &mut sparse_opt[g])
                            as u64;
                        payload.push(sharded[g].table_mut().take_delta());
                    }
                    (expired, payload)
                });
                // Shard delta payload: per group, header + removed ids
                // + full rows (values + Adam state at the group's dim)
                // — the same size whether or not the snapshot is
                // actually written.
                let mut upserts_total = 0u64;
                let mut my_sync_bytes = 0u64;
                for (g, (ups, rem)) in group_payload.iter().enumerate() {
                    let row_bytes = 8 + 3 * plan.groups[g].dim * 4 + 8;
                    my_sync_bytes += (24 + ups.len() * row_bytes + rem.len() * 8) as u64;
                    upserts_total += ups.len() as u64;
                }
                if let Some(dir) = &ocfg.sync_dir {
                    let written = phases.time("6_online_sync", || -> Result<usize> {
                        let rows: Vec<Vec<crate::checkpoint::SparseRow>> = group_payload
                            .iter()
                            .enumerate()
                            .map(|(g, (ups, _))| {
                                crate::checkpoint::delta::collect_rows(
                                    sharded[g].table().inner(),
                                    &sparse_opt[g],
                                    ups,
                                )
                            })
                            .collect();
                        let shards: Vec<crate::checkpoint::delta::GroupDelta> = group_payload
                            .iter()
                            .enumerate()
                            .map(|(g, (_, rem))| crate::checkpoint::delta::GroupDelta {
                                dim: plan.groups[g].dim,
                                upserts: &rows[g],
                                removed: rem,
                                policy: sharded[g].table().inner().precision(),
                            })
                            .collect();
                        let dmeta = DeltaMeta {
                            seq,
                            world,
                            step: (step + 1) as u64,
                            base_step: (step + 1 - ocfg.sync_interval) as u64,
                            model: opts.model.clone(),
                            dim: d,
                            param_count: params.len(),
                        };
                        let dense = (rank == 0).then_some((&params[..], &dense_opt));
                        crate::checkpoint::delta::save_delta_groups(
                            dir, &dmeta, rank, dense, &shards,
                        )
                    })?;
                    my_sync_bytes = written as u64;
                }
                // Simulated push of this rank's delta to serving rides
                // the network model; the step completes when the slowest
                // rank's push does.
                my_sync_s = opts.net.delta_sync_time(world, my_sync_bytes as usize);
                let (adm_total, rej_total) =
                    sharded.iter().fold((0u64, 0u64), |acc, se| {
                        let (a, r) = se.table().admission_totals();
                        (acc.0 + a, acc.1 + r)
                    });
                let my_counts = [
                    adm_total - prev_admitted,
                    rej_total - prev_rejected,
                    expired,
                    upserts_total,
                    my_sync_bytes,
                ];
                prev_admitted = adm_total;
                prev_rejected = rej_total;
                for (slot, mine) in online_counts.iter_mut().zip(my_counts) {
                    *slot = comm.all_gather_u64(mine).iter().sum();
                }
                // Delta `seq` is durable on EVERY rank here (the
                // gathers above are a rendezvous) — the coordinator's
                // step barrier and the torn-publish fault point.
                if let Some(h) = &dist_hooks {
                    h.on_interval(seq)?;
                }
            }
        }

        // ---- bookkeeping (collective gathers for the records) ---------
        // Per-lane wire delta since the previous capture, with the
        // multiplexed packing headers peeled off into their own meter so
        // lanes 1–4 carry exactly the sparse-exchange payload.
        // Attribution follows the posting schedule: a cross-step post
        // counts in the step that posted it — identical in both mux
        // modes, so conservation still holds step by step. Lane 0 also
        // carries the bookkeeping collectives below from the *previous*
        // capture, which is why conservation is only asserted on the
        // exchange lanes.
        let mut my_wire = [0u64; 11];
        for l in 0..LANES {
            let lane_total = comm.stats.lane_bytes[l] - wire_prev[l];
            let hdr = exchange.header_bytes[l] - hdr_prev[l];
            my_wire[l] = lane_total - hdr;
            my_wire[5] += hdr;
        }
        wire_prev = comm.stats.lane_bytes;
        hdr_prev = exchange.header_bytes;
        // Mixed-precision meters: per-step wire deltas by row precision
        // (slots 6–8, all-destination payload including loopback) and
        // the hot/cold row census at the step boundary (slots 9–10).
        // All zero — and the census skipped — in fp32 mode.
        let mixed_precision =
            opts.precision == crate::embedding::precision::PrecisionMode::Mixed;
        let mut pwire_now = crate::embedding::sharded::PrecisionWireBytes::default();
        for se in sharded.iter() {
            pwire_now.merge(&se.precision_wire);
        }
        my_wire[6] = pwire_now.fp32_row_bytes - pwire_prev.fp32_row_bytes;
        my_wire[7] = pwire_now.fp16_row_bytes - pwire_prev.fp16_row_bytes;
        my_wire[8] = pwire_now.tag_bytes - pwire_prev.tag_bytes;
        pwire_prev = pwire_now;
        if mixed_precision {
            for se in sharded.iter() {
                let ps = se.table().inner().precision_stats();
                my_wire[9] += ps.hot_rows as u64;
                my_wire[10] += ps.cold_rows as u64;
            }
        }
        let wire_gathered: Vec<Vec<u64>> = comm
            .all_gather(crate::collective::comm::Message::Counts(my_wire.to_vec()))
            .into_iter()
            .map(|m| m.into_counts())
            .collect();
        let mut wire_payload_bytes = vec![0u64; LANES];
        let mut wire_header_bytes = 0u64;
        let mut wire_fp32_row_bytes = 0u64;
        let mut wire_fp16_row_bytes = 0u64;
        let mut wire_tag_bytes = 0u64;
        let mut hot_rows = 0u64;
        let mut cold_rows = 0u64;
        for w in &wire_gathered {
            for l in 0..LANES {
                wire_payload_bytes[l] += w[l];
            }
            wire_header_bytes += w[5];
            wire_fp32_row_bytes += w[6];
            wire_fp16_row_bytes += w[7];
            wire_tag_bytes += w[8];
            hot_rows += w[9];
            cold_rows += w[10];
        }
        let tokens = comm.all_gather_u64(my_tokens);
        let samples: u64 = comm.all_gather_u64(my_samples).iter().sum();
        // Scenario telemetry, gathered collectively so every rank's
        // records stay identical: batcher carry-over and resident rows
        // sum across ranks, evictions are per-step deltas summed, and
        // the day is the max stamp any rank's stream has reached.
        let my_resident: u64 = sharded
            .iter()
            .map(|s| {
                use crate::embedding::EmbeddingStore;
                EmbeddingStore::len(s.table()) as u64
            })
            .sum();
        let evict_now: u64 = sharded
            .iter()
            .map(|s| s.table().inner().stats().evictions)
            .sum();
        let my_evictions = evict_now - evict_prev;
        evict_prev = evict_now;
        let scen_gathered: Vec<Vec<u64>> = comm
            .all_gather(crate::collective::comm::Message::Counts(vec![
                my_carryover,
                my_resident,
                my_evictions,
                my_day,
            ]))
            .into_iter()
            .map(|m| m.into_counts())
            .collect();
        let mut batcher_carryover = 0u64;
        let mut resident_rows = 0u64;
        let mut evictions = 0u64;
        let mut online_day = 0u64;
        for s in &scen_gathered {
            batcher_carryover += s[0];
            resident_rows += s[1];
            evictions += s[2];
            online_day = online_day.max(s[3]);
        }
        let mut losses = [step_loss[0] as f32, step_loss[1] as f32, my_samples as f32];
        comm.all_reduce_sum(&mut losses);

        // Simulated device time: compute + local lookup + exposed
        // exchange. With overlap on, three lanes hide behind compute in
        // priority order — the ID exchange, then the embedding reply
        // (double-buffered round), then the backward gradient push
        // (completed behind the next round's forward). Cross-step mode
        // additionally hides the first round's ID share behind the
        // previous step's dense sync (the boundary lane). Fig. 12's
        // decomposition reports every share. Lookup cost and wire bytes
        // accumulate per group at the group's width (identical to the
        // historical single-width formulas when there is one group).
        let mut t_lookup = 0.0f64;
        let mut emb_bytes = 0usize;
        let mut ids_moved = 0usize;
        for g in 0..n_groups {
            let lookups_g = dv[g].lookups_done - vol_prev[g].lookups_done;
            let rows_g = dv[g].emb_rows_sent - vol_prev[g].emb_rows_sent;
            ids_moved += dv[g].ids_sent - vol_prev[g].ids_sent;
            t_lookup += opts.device.lookup_time(lookups_g, rows_g, plan.groups[g].dim);
            emb_bytes += rows_g * plan.groups[g].dim * 4;
        }
        vol_prev = dv;
        let t_compute = opts.device.compute_time(my_flops);
        let pairs = world.max(1).pow(2).max(1);
        let emb_bytes_per_pair = emb_bytes / pairs;
        let id_bytes_per_pair = (ids_moved * 8) / pairs;
        let t_reply_comm = opts.net.all_to_all_uniform_time(world, emb_bytes_per_pair.max(1));
        let t_grad_comm = t_reply_comm;
        let t_id_comm = opts.net.all_to_all_uniform_time(world, id_bytes_per_pair.max(1));
        // Only rounds actually pipelined ahead can hide their exchange:
        // the first round's IDs are completed right after posting, the
        // last round's reply/gradients have no successor compute to
        // hide behind — so with R rounds at most (R-1)/R of each lane's
        // traffic is pipelined, and it can only hide behind the same
        // (R-1)/R share of the step's compute. Cross-step pipelining
        // recovers the first round's 1/R ID share by posting it during
        // the previous step's boundary (steps after the first).
        let pipelined_frac = if opts.overlap && rounds > 0 {
            (rounds - 1) as f64 / rounds as f64
        } else {
            0.0
        };
        let t_first_id = if rounds > 0 {
            t_id_comm / rounds as f64
        } else {
            0.0
        };
        // The dense all-reduce window hides two boundary lanes in
        // priority order: the next step's first ID post (steps after the
        // first) and this step's last gradient push (the cross-step
        // gradient lane, which stays in flight across the all-reduce and
        // drains inside the dense sync).
        let t_last_grad = if rounds > 0 {
            t_grad_comm / rounds as f64
        } else {
            0.0
        };
        let bshares = crate::metrics::overlap_exposure_lanes(
            t_allreduce,
            &[if step > 0 { t_first_id } else { 0.0 }, t_last_grad],
            cross,
        );
        let t_hidden_boundary = bshares[0].1;
        let t_hidden_boundary_grad = bshares[1].1;
        let t_window = t_compute * pipelined_frac;
        let hideable = [
            t_id_comm * pipelined_frac,
            t_reply_comm * pipelined_frac,
            t_grad_comm * pipelined_frac,
        ];
        let shares =
            crate::metrics::overlap_exposure_lanes(t_window, &hideable, opts.overlap);
        let t_exposed_comm = (t_id_comm - hideable[0] - t_hidden_boundary).max(0.0)
            + shares[0].0
            + (t_reply_comm - hideable[1]) + shares[1].0
            + (t_grad_comm - hideable[2] - t_hidden_boundary_grad).max(0.0)
            + shares[2].0;
        let my_sim = t_compute + t_lookup + t_exposed_comm;
        let gathered: Vec<Vec<f32>> = comm
            .all_gather(crate::collective::comm::Message::Floats(vec![
                my_sim as f32,
                t_exposed_comm as f32,
                shares[0].1 as f32,
                shares[1].1 as f32,
                shares[2].1 as f32,
                t_hidden_boundary as f32,
                t_hidden_boundary_grad as f32,
                my_sync_s as f32,
            ]))
            .into_iter()
            .map(|m| m.into_floats())
            .collect();
        let sim_all: Vec<f64> = gathered.iter().map(|v| v[0] as f64).collect();
        let comm_all: Vec<f64> = gathered.iter().map(|v| v[1] as f64).collect();
        let hidden_all: Vec<f64> = gathered.iter().map(|v| v[2] as f64).collect();
        let hidden_reply_all: Vec<f64> = gathered.iter().map(|v| v[3] as f64).collect();
        let hidden_grad_all: Vec<f64> = gathered.iter().map(|v| v[4] as f64).collect();
        let hidden_boundary_all: Vec<f64> = gathered.iter().map(|v| v[5] as f64).collect();
        let hidden_boundary_grad_all: Vec<f64> =
            gathered.iter().map(|v| v[6] as f64).collect();
        // Delta-sync push completes at the slowest rank; zero except on
        // online interval boundaries, so offline step times are
        // untouched bit-for-bit.
        let max_sync = gathered
            .iter()
            .map(|v| v[7] as f64)
            .fold(0.0, f64::max);
        let sim_step = sim_all.iter().cloned().fold(0.0, f64::max) + t_allreduce + max_sync;

        let wall_s = step_t0.elapsed().as_secs_f64();
        wall.add(samples, tokens.iter().sum(), wall_s);
        records.push(StepRecord {
            step,
            // losses[0/1] are global loss sums; losses[2] is the global
            // sample count — the ratio is the global per-sample mean.
            loss_ctr: losses[0] as f64 / losses[2].max(1.0) as f64,
            loss_ctcvr: losses[1] as f64 / losses[2].max(1.0) as f64,
            samples,
            tokens,
            sim_device_s: sim_all,
            sim_exposed_comm_s: comm_all,
            sim_hidden_comm_s: hidden_all,
            sim_hidden_reply_s: hidden_reply_all,
            sim_hidden_grad_s: hidden_grad_all,
            sim_hidden_boundary_s: hidden_boundary_all,
            sim_hidden_boundary_grad_s: hidden_boundary_grad_all,
            sim_step_s: sim_step,
            sim_sync_s: max_sync,
            wall_s,
            // §4.2 operator fusion made measurable: ops actually issued
            // (one per group per round) vs what an unmerged layout would
            // have issued (one per logical table per round). Identical
            // on every rank — rounds are collectively aligned.
            lookup_ops_merged: rounds as u64 * plan.ops_after as u64,
            lookup_ops_unmerged: rounds as u64 * plan.ops_before as u64,
            online_admitted: online_counts[0],
            online_rejected: online_counts[1],
            online_expired: online_counts[2],
            online_synced_rows: online_counts[3],
            online_sync_bytes: online_counts[4],
            wire_payload_bytes,
            wire_header_bytes,
            batcher_carryover,
            resident_rows,
            online_day,
            evictions,
            wire_fp32_row_bytes,
            wire_fp16_row_bytes,
            wire_tag_bytes,
            hot_rows,
            cold_rows,
        });
        // Endless runs would otherwise grow the record log without
        // bound; keep a rolling tail (`step` fields stay absolute).
        if total_steps.is_none() && records.len() >= 2 * ENDLESS_RECORD_CAP {
            records.drain(..ENDLESS_RECORD_CAP);
        }
        if rank == 0 && opts.log_every > 0 && (step + 1) % opts.log_every == 0 {
            let r = records.last().unwrap();
            eprintln!(
                "step {:>5}  loss_ctr {:.4}  loss_ctcvr {:.4}  samples {}  sim_step {:.2} ms",
                step + 1,
                r.loss_ctr,
                r.loss_ctcvr,
                r.samples,
                r.sim_step_s * 1e3
            );
        }
        step += 1;
    }
    debug_assert!(posted.is_none(), "a posted lookup outlived the run");

    // Per-group aggregates plus their cross-group sums (the historical
    // scalar fields are the sums, so single-group reports are
    // unchanged).
    let group_checksums: Vec<u64> = sharded
        .iter()
        .map(|s| s.table().inner().content_checksum())
        .collect();
    let group_rows: Vec<usize> = sharded
        .iter()
        .map(|s| {
            use crate::embedding::EmbeddingStore;
            EmbeddingStore::len(s.table())
        })
        .collect();
    let group_volumes: Vec<DedupVolume> = sharded.iter().map(|s| s.volume).collect();
    let mut volume = DedupVolume::default();
    for v in &group_volumes {
        volume.merge(v);
    }
    let mut table_stats = TableStats::default();
    for s in &sharded {
        table_stats.merge(&s.table().inner().stats());
    }
    Ok(WorkerOutput {
        rank,
        steps: records,
        gauc_ctr,
        gauc_ctcvr,
        phases,
        wall,
        table_rows: group_rows.iter().sum(),
        table_memory: {
            use crate::embedding::EmbeddingStore;
            sharded
                .iter()
                .map(|s| EmbeddingStore::memory_bytes(s.table()))
                .sum()
        },
        volume,
        truncated,
        prefetch_occupancy: stream.depth_occupancy(),
        table_checksum: group_checksums
            .iter()
            .fold(0u64, |a, &c| a.wrapping_add(c)),
        table_stats,
        group_dims: plan.group_dims(),
        group_volumes,
        group_checksums,
        group_rows,
        transport_retries: comm.transport_retries(),
        scenario: opts.scenario.as_ref().map(|s| s.name.to_string()),
        fill_denom: if opts.train.sequence_balancing {
            (opts.train.target_tokens * world) as u64
        } else {
            0
        },
        precision: opts.precision.as_str().to_string(),
        precision_stats: {
            let mut ps = crate::embedding::precision::PrecisionStats::default();
            if opts.precision == crate::embedding::precision::PrecisionMode::Mixed {
                for s in &sharded {
                    ps.merge(&s.table().inner().precision_stats());
                }
            }
            ps
        },
        effective_value_bytes: sharded
            .iter()
            .map(|s| s.table().inner().effective_value_bytes() as u64)
            .sum(),
    })
}

/// Keep only the (id, gradient-row) pairs whose row is live in `table`
/// — online mode's guard against training rows that admission rejected
/// or the TTL sweeper retired. Single pass: one striped `contains` per
/// id (admission rejects something on virtually every online step, so
/// an all-present fast path would just double the lock traffic).
fn filter_present(
    table: &ConcurrentDynamicTable,
    ids: Vec<GlobalId>,
    grads: Vec<f32>,
    d: usize,
) -> (Vec<GlobalId>, Vec<f32>) {
    let mut out_ids = Vec::with_capacity(ids.len());
    let mut out_grads = Vec::with_capacity(grads.len());
    for (i, &id) in ids.iter().enumerate() {
        if table.contains(id) {
            out_ids.push(id);
            out_grads.extend_from_slice(&grads[i * d..(i + 1) * d]);
        }
    }
    (out_ids, out_grads)
}

/// Split a balanced batch into engine micro-batches, choosing for each
/// the smallest compiled bucket that fits.
fn split_micros(batch: Batch, arts: &crate::runtime::ModelArtifacts) -> Vec<Micro> {
    let max_b = arts.largest_bucket().batch;
    let mut out = Vec::new();
    let mut seqs = batch.sequences;
    while !seqs.is_empty() {
        let take = seqs.len().min(max_b);
        let chunk: Vec<_> = seqs.drain(..take).collect();
        let max_len = chunk.iter().map(|s| s.len()).max().unwrap_or(0);
        let bucket = arts
            .pick_bucket(chunk.len(), max_len)
            .unwrap_or_else(|| arts.largest_bucket());
        let tokens = chunk.iter().map(|s| s.len()).sum();
        out.push(Micro {
            batch: Batch {
                sequences: chunk,
                tokens,
            },
            bucket: (bucket.batch, bucket.len),
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{Bucket, ModelArtifacts};

    fn fake_arts() -> ModelArtifacts {
        ModelArtifacts {
            name: "t".into(),
            emb_dim: 8,
            heads: 2,
            blocks: 1,
            tasks: 2,
            param_count: 10,
            params_bin: "x".into(),
            params_seed: 0,
            arch: crate::runtime::ModelArch::MeanPool,
            buckets: vec![
                Bucket {
                    batch: 4,
                    len: 32,
                    train: "a".into(),
                    forward: "b".into(),
                },
                Bucket {
                    batch: 8,
                    len: 64,
                    train: "c".into(),
                    forward: "d".into(),
                },
            ],
        }
    }

    fn seqs_of_lens(lens: &[usize]) -> Batch {
        let sequences: Vec<_> = lens
            .iter()
            .map(|&l| crate::data::schema::Sequence {
                user_id: l as u64,
                context: vec![0, 0, 0],
                tokens: vec![vec![0, 0, 0, 0]; l],
                labels: [0.0, 0.0],
            })
            .collect();
        Batch {
            tokens: lens.iter().sum(),
            sequences,
        }
    }

    #[test]
    fn split_micros_respects_buckets() {
        let arts = fake_arts();
        // 10 sequences of length ≤ 32 → micro of 8 + micro of 2.
        let micros = split_micros(seqs_of_lens(&[10; 10]), &arts);
        assert_eq!(micros.len(), 2);
        assert_eq!(micros[0].batch.sequences.len(), 8);
        assert_eq!(micros[0].bucket, (8, 64));
        assert_eq!(micros[1].batch.sequences.len(), 2);
        assert_eq!(micros[1].bucket, (4, 32), "small tail fits small bucket");
    }

    #[test]
    fn split_micros_length_drives_bucket() {
        let arts = fake_arts();
        let micros = split_micros(seqs_of_lens(&[40, 5]), &arts);
        assert_eq!(micros.len(), 1);
        assert_eq!(micros[0].bucket, (8, 64), "long sequence needs big bucket");
    }

    #[test]
    fn split_micros_empty() {
        let arts = fake_arts();
        assert!(split_micros(seqs_of_lens(&[]), &arts).is_empty());
    }
}
