//! # MTGRBoost
//!
//! A reproduction of *MTGRBoost: Boosting Large-scale Generative
//! Recommendation Models in Meituan* (KDD 2026) as a three-layer
//! Rust + JAX + Pallas system.
//!
//! Layer 3 (this crate) is the distributed-training coordinator: dynamic
//! hash embedding tables (single-threaded and lock-striped concurrent),
//! automatic table merging, two-stage ID deduplication with a pipelined
//! two-phase exchange, dynamic sequence balancing, hybrid-parallel
//! training (model-parallel sparse + data-parallel dense), checkpoint
//! resharding, mixed precision, and gradient accumulation. Layers 2/1
//! (JAX model and the Pallas HSTU kernel under `python/compile/`) are
//! AOT-compiled to HLO text at build time and executed from Rust via
//! PJRT behind the `pjrt` feature; the default build executes the same
//! artifact contract on the deterministic reference CPU backend
//! ([`runtime::reference`]), so training, tests and CI run fully
//! offline. Python never runs on the training hot path.
//!
//! Entry points:
//! - [`config`] — model / cluster / training configuration (GRM presets).
//! - [`train::Trainer`] — the synchronous multi-worker training loop;
//!   `TrainerOptions::overlap` pipelines micro-batch *k+1*'s ID
//!   all-to-all behind micro-batch *k*'s compute.
//! - [`embedding`] — the paper's sparse-side contribution (§4):
//!   [`embedding::EmbeddingStore`] for exclusive stores,
//!   [`embedding::ConcurrentEmbeddingStore`] +
//!   [`embedding::concurrent::ConcurrentDynamicTable`] for lock-striped
//!   concurrent shards, and
//!   [`embedding::sharded::ShardedEmbedding::post_ids`] /
//!   [`embedding::sharded::ShardedEmbedding::complete_lookup`] — the
//!   two-phase sharded exchange over the communicator's posted
//!   (isend/irecv-style) all-to-all lanes.
//! - [`balance`] — dynamic sequence balancing (§5.1, Algorithm 1).
//! - [`sim`] — analytic multi-node scale simulator for the §6
//!   experiments, including the overlap (hidden-communication) model.

pub mod balance;
pub mod checkpoint;
pub mod collective;
pub mod config;
pub mod data;
pub mod optim;
pub mod metrics;
pub mod runtime;
pub mod sim;
pub mod train;
pub mod embedding;
pub mod util;
