//! # MTGRBoost
//!
//! A reproduction of *MTGRBoost: Boosting Large-scale Generative
//! Recommendation Models in Meituan* (KDD 2026) as a three-layer
//! Rust + JAX + Pallas system.
//!
//! Layer 3 (this crate) is the distributed-training coordinator: dynamic
//! hash embedding tables (single-threaded and lock-striped concurrent),
//! automatic table merging, two-stage ID deduplication with a pipelined
//! two-phase exchange, dynamic sequence balancing, hybrid-parallel
//! training (model-parallel sparse + data-parallel dense), checkpoint
//! resharding, mixed precision, and gradient accumulation. Layers 2/1
//! (JAX model and the Pallas HSTU kernel under `python/compile/`) are
//! AOT-compiled to HLO text at build time and executed from Rust via
//! PJRT behind the `pjrt` feature; the default build executes the same
//! artifact contract on the deterministic reference CPU backend
//! ([`runtime::reference`]), so training, tests and CI run fully
//! offline. Python never runs on the training hot path.
//!
//! Entry points:
//! - [`config`] — model / cluster / training configuration (GRM presets).
//! - [`train::Trainer`] — the synchronous multi-worker training loop;
//!   `TrainerOptions::overlap` runs the fully double-buffered exchange
//!   (micro-batch *k+1*'s ID all-to-all and *k*'s embedding reply in
//!   flight together, *k*'s gradient push completed behind *k+1*'s
//!   forward), `TrainerOptions::cross_step` extends the double buffer
//!   across step boundaries in **both directions** (step *s+1*'s first
//!   ID exchange posts before step *s*'s dense all-reduce + optimizer
//!   apply, and step *s*'s last gradient push stays in flight across
//!   the same window, with the hidden time reported on the
//!   `sim_hidden_boundary_s` / `sim_hidden_boundary_grad_s` lanes),
//!   `TrainerOptions::multiplex_exchange` packs every merge group's
//!   exchange into one message per comm lane
//!   ([`embedding::sharded::GroupExchange`], `--no-multiplex` to
//!   ablate; per-lane payload bytes are metered in `StepRecord` and
//!   asserted conserved against the per-group schedule),
//!   `TrainerOptions::table_merging` (`--no-merging`) ablates §4.2
//!   fusion to one exchange per logical table, and
//!   `TrainerOptions::threads` sizes the **one process-global**
//!   [`util::pool::WorkerPool`] shared by every worker — each worker
//!   chunks on a deterministic fair-share view
//!   ([`util::pool::WorkerPool::fair_share`], `⌈threads/world⌉`), so
//!   the host never runs `world × threads` threads. Numerics are
//!   bit-identical for every combination.
//! - [`runtime::reference`] — the deterministic CPU executor now chunks
//!   the dense forward/backward over the batch on the shared pool
//!   (fixed chunk count; per-chunk partial loss/gradient reductions
//!   folded in chunk order, so every pool size is bit-identical) and
//!   writes into a reusable [`runtime::TrainScratch`] arena;
//!   reference-backend engines execute it inline on the calling worker
//!   instead of serializing through the engine channel.
//! - [`embedding`] — the paper's sparse-side contribution (§4):
//!   [`embedding::EmbeddingStore`] for exclusive stores (with batched
//!   `fetch_rows`), [`embedding::ConcurrentEmbeddingStore`] +
//!   [`embedding::concurrent::ConcurrentDynamicTable`] for lock-striped
//!   concurrent shards with stripe-bucketed parallel fetch, and
//!   [`embedding::sharded::ShardedEmbedding::post_ids`] /
//!   [`embedding::sharded::ShardedEmbedding::serve_reply`] /
//!   [`embedding::sharded::ShardedEmbedding::complete_reply`] plus
//!   [`embedding::sharded::ShardedEmbedding::post_backward`] /
//!   [`embedding::sharded::ShardedEmbedding::complete_backward`] — the
//!   three-phase sharded exchange over the communicator's posted
//!   (isend/irecv-style) all-to-all lanes.
//! - [`embedding::merge`] — automatic table merging (§4.2) end to end:
//!   `--schema meituan-mixed` declares heterogeneous feature dims (8D
//!   context + model-dim token features with a `shared_table` alias),
//!   [`embedding::merge::MergePlan`] folds them into one physical
//!   table per dim group, and the trainer runs the **entire**
//!   distributed path per group — per-group occurrence streams
//!   ([`train::features::BatchIds`]), per-group sharded exchanges and
//!   dedup, per-group row-wise Adam, and per-group checkpoint/delta
//!   shards — with fused-vs-unmerged lookup-op counts surfaced in
//!   `StepRecord`/`TrainReport`. Homogeneous schemas form exactly one
//!   group and stay byte-identical to the historical single-table
//!   path (the single-group compatibility guarantee).
//! - [`embedding::dedup`] — two-stage dedup with a size-switched
//!   hash/sort kernel ([`embedding::dedup::DedupKernel`]),
//!   pool-parallel sort, gather and scatter kernels, and cache-blocked
//!   inner loops (`gather_rows` / `scatter_accumulate` /
//!   [`optim::adam::SparseAdam`] process rows in fixed-width blocks
//!   with fixed-dim fast paths — bit-identical to the scalar loops by
//!   construction, property-tested in `tests/simd_kernels.rs`). The
//!   kernel switch points are runtime-tunable ([`util::tuning`]):
//!   `MTGR_DEDUP_SORT_THRESHOLD` / `MTGR_PAR_ROWS_THRESHOLD` /
//!   `MTGR_PAR_FETCH_THRESHOLD` / `MTGR_PAR_DENSE_THRESHOLD`, with the
//!   calibrated defaults baked in [`util::tuning::calibrated`] and
//!   re-measured per machine by `bench_parallel_lookup --calibrate`
//!   (which writes `calibration.json`).
//! - [`embedding::precision`] — mixed-precision storage and wire
//!   compression (§5.2, `--precision mixed` / `--hot-threshold`): one
//!   deterministic post-bump rule classifies each row hot or cold
//!   (access count *after* the current op's bump ≥ threshold), hot
//!   rows keep full FP32 state while cold rows are stored on the
//!   binary16 grid (every write path re-quantizes under the stripe
//!   lock, so stored cold bits are *always* f16-exact), and the
//!   sharded exchange ships cold embedding replies and cold gradient
//!   pushes as packed FP16 with per-row precision tags on the
//!   existing multiplexed lanes. Bytes-by-precision meters and the
//!   hot/cold census land in `StepRecord`/`TrainReport`; checkpoints
//!   and deltas record the policy (absent keys = fp32, so fp32
//!   snapshots stay byte-identical) so serving replicas, compaction
//!   and `train-dist` recovery round-trip cold rows on the exact f16
//!   grid — installs copy stored bits verbatim, no dequantization.
//!   Numerics are bit-identical across `--threads` × `--overlap` ×
//!   `--cross-step` × `--multiplex`, and `--precision fp32` (the
//!   default) is byte-identical to pre-policy builds. `bench_precision`
//!   measures the wire/storage wins against the fp32 baseline at equal
//!   losses.
//! - [`online`] — the online-learning subsystem (`--mode online`): an
//!   endless day-advancing stream ([`online::stream`]), count-min
//!   feature admission with a deterministic seeded lottery
//!   ([`online::admission`] — rare one-shot IDs never allocate rows),
//!   the [`online::OnlineTable`] gate layering per-row touch stamps
//!   (TTL input) and [`online::delta::DeltaTracker`] change tracking
//!   over the concurrent shard, a TTL sweeper retiring stale rows, and
//!   incremental delta snapshots ([`checkpoint::delta`]) emitted every
//!   `--sync-interval` steps that a serving replica applies on top of a
//!   base snapshot to reconstruct the exact training state row for row.
//!   Admission decisions are pure functions of `(seed, id, count)` and
//!   every sweep/drain runs in sorted id order, so online runs are
//!   bit-identical across `--threads` — including the emitted delta
//!   bytes.
//! - [`scenario`] — named adversarial / long-run workload presets
//!   (`--scenario skew-storm|churn-storm|multi-tenant|soak`): each is a
//!   declarative spec that reshapes the generator, picks a schema,
//!   tunes admission (count-min day decay, re-admission hysteresis)
//!   and carries per-group row budgets — composing with the existing
//!   stream/online stack rather than forking it. Per-scenario
//!   telemetry (admission/eviction churn, batcher fill/carry-over,
//!   peak resident rows) lands in `StepRecord`/`TrainReport`;
//!   `bench_scenarios` runs each preset and the soak suite asserts
//!   bounded resident state over multi-day runs.
//! - [`serve`] — the consumer end of the train→sync→serve loop: a
//!   read-optimized [`serve::ServingReplica`] that folds the trainer's
//!   rank shards into one striped table per merge group and
//!   continuously applies validated delta chains (gapped or torn
//!   chains are hard errors, never silent staleness), log-structured
//!   compaction ([`serve::compact`]) folding base + deltas into fresh
//!   crash-safe `base_<seq>` snapshots so replay cost stays bounded, a
//!   direct-mapped hot-ID cache with per-delta invalidation, and a
//!   deterministic closed-loop traffic generator (Zipf users, diurnal
//!   bursts) driving micro-batched lookup + dense-forward serving —
//!   measured by `bench_serving` as p50/p99 latency and achieved QPS
//!   versus `--sync-interval`.
//! - [`dist`] — the fault-tolerant multi-process runtime
//!   (`train-dist`): a real byte transport over Unix-domain sockets
//!   ([`dist::SocketTransport`], length-prefixed frames, one stream per
//!   ordered rank pair) behind the communicator's
//!   [`collective::RemoteTransport`] seam, a coordinator
//!   ([`dist::Coordinator`]) doing registration, seeded shard
//!   assignment, interval barriers and heartbeat failure detection
//!   (pure [`dist::HeartbeatTracker`]), a deterministic fault harness
//!   ([`dist::FaultPlan`]: kill at step, drop/delay a frame, torn
//!   checkpoint publish), and a supervisor ([`dist::run_dist`]) that
//!   recovers from any worker death by gang restart from the newest
//!   CRC-durable delta — with the drill suite asserting recovered runs
//!   are bit-identical to uninterrupted ones. Every failure event
//!   (heartbeat misses, transport retries, recoveries, replayed steps)
//!   lands in `TrainReport::dist`.
//! - [`util::retry`] — deterministic retry/backoff (pure jittered
//!   schedule) used by the transport; [`util::crc32`] — the CRC32
//!   footer sealing every checkpoint/delta row file against torn or
//!   bit-flipped reads.
//! - [`util::pool`] — the deterministic work-stealing-free worker pool
//!   (`parallel_for` / `parallel_map` over stable index chunks), with
//!   fair-share views for concurrent callers of one global pool.
//! - [`balance`] — dynamic sequence balancing (§5.1, Algorithm 1).
//! - [`data::prefetch`] — drop-joined background batch prefetcher with
//!   queue-occupancy reporting.
//! - [`sim`] — analytic multi-node scale simulator for the §6
//!   experiments, including the per-lane overlap (hidden-communication)
//!   model.

pub mod balance;
pub mod checkpoint;
pub mod collective;
pub mod config;
pub mod data;
pub mod dist;
pub mod online;
pub mod optim;
pub mod metrics;
pub mod runtime;
pub mod scenario;
pub mod serve;
pub mod sim;
pub mod train;
pub mod embedding;
pub mod util;
