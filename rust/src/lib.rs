//! # MTGRBoost
//!
//! A reproduction of *MTGRBoost: Boosting Large-scale Generative
//! Recommendation Models in Meituan* (KDD 2026) as a three-layer
//! Rust + JAX + Pallas system.
//!
//! Layer 3 (this crate) is the distributed-training coordinator: dynamic
//! hash embedding tables (single-threaded and lock-striped concurrent),
//! automatic table merging, two-stage ID deduplication with a pipelined
//! two-phase exchange, dynamic sequence balancing, hybrid-parallel
//! training (model-parallel sparse + data-parallel dense), checkpoint
//! resharding, mixed precision, and gradient accumulation. Layers 2/1
//! (JAX model and the Pallas HSTU kernel under `python/compile/`) are
//! AOT-compiled to HLO text at build time and executed from Rust via
//! PJRT behind the `pjrt` feature; the default build executes the same
//! artifact contract on the deterministic reference CPU backend
//! ([`runtime::reference`]), so training, tests and CI run fully
//! offline. Python never runs on the training hot path.
//!
//! Entry points:
//! - [`config`] — model / cluster / training configuration (GRM presets).
//! - [`train::Trainer`] — the synchronous multi-worker training loop;
//!   `TrainerOptions::overlap` runs the fully double-buffered exchange
//!   (micro-batch *k+1*'s ID all-to-all and *k*'s embedding reply in
//!   flight together, *k*'s gradient push completed behind *k+1*'s
//!   forward) and `TrainerOptions::threads` sizes each worker's shared
//!   [`util::pool::WorkerPool`] — numerics are bit-identical for every
//!   combination.
//! - [`embedding`] — the paper's sparse-side contribution (§4):
//!   [`embedding::EmbeddingStore`] for exclusive stores (with batched
//!   `fetch_rows`), [`embedding::ConcurrentEmbeddingStore`] +
//!   [`embedding::concurrent::ConcurrentDynamicTable`] for lock-striped
//!   concurrent shards with stripe-bucketed parallel fetch, and
//!   [`embedding::sharded::ShardedEmbedding::post_ids`] /
//!   [`embedding::sharded::ShardedEmbedding::serve_reply`] /
//!   [`embedding::sharded::ShardedEmbedding::complete_reply`] plus
//!   [`embedding::sharded::ShardedEmbedding::post_backward`] /
//!   [`embedding::sharded::ShardedEmbedding::complete_backward`] — the
//!   three-phase sharded exchange over the communicator's posted
//!   (isend/irecv-style) all-to-all lanes.
//! - [`embedding::dedup`] — two-stage dedup with a size-switched
//!   hash/sort kernel ([`embedding::dedup::DedupKernel`]) and
//!   pool-parallel sort, gather and scatter kernels.
//! - [`util::pool`] — the deterministic work-stealing-free worker pool
//!   (`parallel_for` / `parallel_map` over stable index chunks).
//! - [`balance`] — dynamic sequence balancing (§5.1, Algorithm 1).
//! - [`data::prefetch`] — drop-joined background batch prefetcher with
//!   queue-occupancy reporting.
//! - [`sim`] — analytic multi-node scale simulator for the §6
//!   experiments, including the per-lane overlap (hidden-communication)
//!   model.

pub mod balance;
pub mod checkpoint;
pub mod collective;
pub mod config;
pub mod data;
pub mod optim;
pub mod metrics;
pub mod runtime;
pub mod sim;
pub mod train;
pub mod embedding;
pub mod util;
