//! Configuration: model presets (Table 1), cluster topology, training
//! hyperparameters, and the feature schema defaults.

mod presets;

// `presets` only adds inherent impls on ModelConfig (no re-exportable items).

use crate::embedding::dedup::DedupStrategy;

/// GRM dense-model hyperparameters (Table 1 shape).
#[derive(Clone, Debug)]
pub struct ModelConfig {
    pub name: String,
    /// Embedding dimension `d` fed to the HSTU stack.
    pub emb_dim: usize,
    /// Number of HSTU blocks.
    pub hstu_blocks: usize,
    /// Attention heads per block.
    pub hstu_heads: usize,
    /// MMoE experts and top-k routing.
    pub experts: usize,
    pub expert_top_k: usize,
    /// Hidden width of each expert MLP.
    pub expert_hidden: usize,
    /// Prediction tasks (CTR, CTCVR).
    pub num_tasks: usize,
    /// Embedding-dimension multiplier for the sparse side (the paper's
    /// 1D/8D/64D factors; scales the merged-table dims, not `emb_dim`).
    pub dim_factor: usize,
}

impl ModelConfig {
    /// Dense parameter count of the HSTU+MMoE stack (matches the L2 JAX
    /// model in `python/compile/model.py`; verified in tests against the
    /// AOT manifest).
    pub fn dense_params(&self) -> usize {
        let d = self.emb_dim;
        // Per HSTU block: input MLP d→4d (w+b), output MLP d→d (w+b),
        // two layernorm scales/biases (2·2d).
        let per_block = d * 4 * d + 4 * d + d * d + d + 4 * d;
        // MMoE: gate per task (d→experts), experts d→h→d, task heads h…
        let expert = self.experts * (d * self.expert_hidden + self.expert_hidden
            + self.expert_hidden * d + d);
        let gates = self.num_tasks * (d * self.experts + self.experts);
        let heads = self.num_tasks * (d + 1);
        self.hstu_blocks * per_block + expert + gates + heads
    }

    /// Forward FLOPs for one sequence of `len` tokens (the basis of the
    /// paper's 4G/110G naming). Attention is quadratic in `len`; MLPs are
    /// linear.
    pub fn forward_flops(&self, len: usize) -> f64 {
        let d = self.emb_dim as f64;
        let l = len as f64;
        let per_block =
            // input MLP d→4d + output MLP d→d per token
            2.0 * l * (4.0 * d * d + d * d)
            // QK^T and PV: 2 · l² · d each
            + 2.0 * 2.0 * l * l * d;
        let mmoe = 2.0 * l * (self.experts as f64)
            * (d * self.expert_hidden as f64 * 2.0);
        self.hstu_blocks as f64 * per_block + mmoe
    }
}

/// Cluster topology for real or simulated runs.
#[derive(Clone, Copy, Debug)]
pub struct ClusterConfig {
    pub world: usize,
    pub gpus_per_node: usize,
}

impl ClusterConfig {
    pub fn new(world: usize) -> Self {
        ClusterConfig {
            world,
            gpus_per_node: 8.min(world.max(1)),
        }
    }

    pub fn nodes(&self) -> usize {
        self.world.div_ceil(self.gpus_per_node)
    }
}

/// Training hyperparameters and feature toggles (the ablation axes).
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub seed: u64,
    /// Target token count N for dynamic sequence balancing (Alg. 1):
    /// average sequence length × batch size.
    pub target_tokens: usize,
    /// Fixed per-device batch size when balancing is disabled.
    pub fixed_batch: usize,
    /// Adam hyperparameters (dense and sparse).
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    /// Gradient accumulation steps (§5.2).
    pub grad_accum: usize,
    // ---- MTGRBoost feature toggles (Fig. 13 ablation axes) -----------
    pub sequence_balancing: bool,
    pub dedup: DedupStrategy,
    pub table_merging: bool,
    pub mixed_precision: bool,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            seed: 2026,
            target_tokens: 8192,
            fixed_batch: 16,
            lr: 1e-3,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            grad_accum: 1,
            sequence_balancing: true,
            dedup: DedupStrategy::TwoStage,
            table_merging: true,
            mixed_precision: false,
        }
    }
}

impl TrainConfig {
    /// The "TorchRec baseline" configuration: every MTGRBoost feature off.
    pub fn torchrec_baseline() -> Self {
        TrainConfig {
            sequence_balancing: false,
            dedup: DedupStrategy::None,
            table_merging: false,
            mixed_precision: false,
            ..Default::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_flops_match_names() {
        // Table 1: Small = 4 GFLOPs, Large = 110 GFLOPs per forward at
        // the average sequence length (600 tokens).
        let small = ModelConfig::grm_4g();
        let large = ModelConfig::grm_110g();
        let f_small = small.forward_flops(600) / 1e9;
        let f_large = large.forward_flops(600) / 1e9;
        // Our estimator counts all matmul FLOPs incl. attention at the
        // mean length; the paper's 4G/110G labels use their own counting
        // convention, so assert order-of-magnitude agreement and, more
        // importantly, the ~27× ratio between the two presets.
        assert!(
            (2.0..15.0).contains(&f_small),
            "small ≈ 4 GFLOPs (order), got {f_small:.1}"
        );
        assert!(
            (60.0..300.0).contains(&f_large),
            "large ≈ 110 GFLOPs (order), got {f_large:.1}"
        );
        let ratio = f_large / f_small;
        assert!(
            (10.0..40.0).contains(&ratio),
            "paper: 27.5x complexity ratio, got {ratio:.1}"
        );
        assert_eq!(small.emb_dim, 512);
        assert_eq!(small.hstu_blocks, 3);
        assert_eq!(small.hstu_heads, 2);
        assert_eq!(large.emb_dim, 1024);
        assert_eq!(large.hstu_blocks, 22);
        assert_eq!(large.hstu_heads, 4);
    }

    #[test]
    fn flops_quadratic_in_length() {
        let m = ModelConfig::grm_4g();
        let f1 = m.forward_flops(1000);
        let f2 = m.forward_flops(2000);
        // Attention-dominated at long lengths: ratio between 2 and 4.
        assert!(f2 / f1 > 2.0 && f2 / f1 < 4.0);
    }

    #[test]
    fn tiny_preset_is_small_enough_for_cpu() {
        let t = ModelConfig::tiny();
        assert!(t.dense_params() < 200_000);
        let s = ModelConfig::small();
        assert!(s.dense_params() > 300_000 && s.dense_params() < 20_000_000);
    }

    #[test]
    fn cluster_nodes() {
        assert_eq!(ClusterConfig::new(8).nodes(), 1);
        assert_eq!(ClusterConfig::new(64).nodes(), 8);
        assert_eq!(ClusterConfig::new(128).nodes(), 16);
        assert_eq!(ClusterConfig::new(4).gpus_per_node, 4);
    }

    #[test]
    fn baseline_config_disables_everything() {
        let b = TrainConfig::torchrec_baseline();
        assert!(!b.sequence_balancing);
        assert!(!b.table_merging);
        assert_eq!(b.dedup, DedupStrategy::None);
    }
}
