//! Model presets.
//!
//! `grm_4g` / `grm_110g` are Table 1 verbatim (used by the analytic
//! scale simulator). `tiny` / `small` are proportionally scaled-down
//! configs for real CPU execution (tests and the e2e example) — same
//! architecture, smaller dims, documented in EXPERIMENTS.md.

use super::ModelConfig;

impl ModelConfig {
    /// Table 1 "Small": 4 GFLOPs/forward, d=512, 3 blocks, 2 heads.
    pub fn grm_4g() -> ModelConfig {
        ModelConfig {
            name: "grm-4g".into(),
            emb_dim: 512,
            hstu_blocks: 3,
            hstu_heads: 2,
            experts: 4,
            expert_top_k: 2,
            expert_hidden: 512,
            num_tasks: 2,
            dim_factor: 1,
        }
    }

    /// Table 1 "Large": 110 GFLOPs/forward, d=1024, 22 blocks, 4 heads.
    pub fn grm_110g() -> ModelConfig {
        ModelConfig {
            name: "grm-110g".into(),
            emb_dim: 1024,
            hstu_blocks: 22,
            hstu_heads: 4,
            experts: 8,
            expert_top_k: 2,
            expert_hidden: 1024,
            num_tasks: 2,
            dim_factor: 1,
        }
    }

    /// CPU-scale config for unit/integration tests (< 0.2 M dense params).
    pub fn tiny() -> ModelConfig {
        ModelConfig {
            name: "grm-tiny".into(),
            emb_dim: 32,
            hstu_blocks: 2,
            hstu_heads: 2,
            experts: 2,
            expert_top_k: 1,
            expert_hidden: 32,
            num_tasks: 2,
            dim_factor: 1,
        }
    }

    /// CPU-scale config for the e2e example (~1–10 M dense params; total
    /// model crosses 100 M parameters through the sparse tables, which is
    /// where recommendation models hold their capacity).
    pub fn small() -> ModelConfig {
        ModelConfig {
            name: "grm-small".into(),
            emb_dim: 128,
            hstu_blocks: 4,
            hstu_heads: 2,
            experts: 4,
            expert_top_k: 2,
            expert_hidden: 128,
            num_tasks: 2,
            dim_factor: 1,
        }
    }

    /// CPU-scale config whose dense side runs the real HSTU attention
    /// blocks in the reference executor (`runtime::reference`) instead
    /// of the mean-pool toy — paper-shaped FLOPs at test scale. Kept
    /// deliberately small (d=16, 1 block) so the O(L²·d) attention stays
    /// fast enough for the bit-identity grids in CI.
    pub fn tiny_hstu() -> ModelConfig {
        ModelConfig {
            name: "grm-tiny-hstu".into(),
            emb_dim: 16,
            hstu_blocks: 1,
            hstu_heads: 2,
            experts: 2,
            expert_top_k: 1,
            expert_hidden: 16,
            num_tasks: 2,
            dim_factor: 1,
        }
    }

    pub fn with_dim_factor(mut self, f: usize) -> ModelConfig {
        self.dim_factor = f;
        self.name = format!("{}-{}d", self.name, f);
        self
    }

    /// Resolve a preset by name (CLI).
    pub fn by_name(name: &str) -> Option<ModelConfig> {
        match name {
            "tiny" => Some(ModelConfig::tiny()),
            "tiny-hstu" => Some(ModelConfig::tiny_hstu()),
            "small" => Some(ModelConfig::small()),
            "4g" | "grm-4g" => Some(ModelConfig::grm_4g()),
            "110g" | "grm-110g" => Some(ModelConfig::grm_110g()),
            _ => None,
        }
    }
}
