//! Evaluation and efficiency metrics.
//!
//! - [`auc`] — rank-based AUC with tie handling.
//! - [`GaucAccumulator`] — *Group AUC* (§6.1): per-user AUC weighted by
//!   the user's positive×negative pair count; "GAUC calculates the AUC
//!   metric by grouping at the user level, which can better reflect the
//!   actual performance of the recommendation model".
//! - [`Throughput`] — samples/sec and tokens/sec meters (the paper's
//!   efficiency metric).
//! - [`DeviceModel`] — analytic A100 device-time model used to convert
//!   measured token counts / byte volumes into *simulated* step times
//!   for the multi-GPU experiments (DESIGN.md substitution #1).

use std::collections::HashMap;

/// Rank-based AUC over (score, label∈{0,1}) pairs; ties share ranks.
/// Returns `None` when only one class is present.
pub fn auc(scores: &[f32], labels: &[f32]) -> Option<f64> {
    assert_eq!(scores.len(), labels.len());
    let n_pos = labels.iter().filter(|&&y| y > 0.5).count();
    let n_neg = labels.len() - n_pos;
    if n_pos == 0 || n_neg == 0 {
        return None;
    }
    // Sort by score; average ranks over tie groups.
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    idx.sort_by(|&a, &b| scores[a].partial_cmp(&scores[b]).unwrap());
    let mut rank_sum_pos = 0.0f64;
    let mut i = 0;
    while i < idx.len() {
        let mut j = i;
        while j + 1 < idx.len() && scores[idx[j + 1]] == scores[idx[i]] {
            j += 1;
        }
        // Ranks are 1-based; tie group [i..=j] shares the average rank.
        let avg_rank = (i + j + 2) as f64 / 2.0;
        for &k in &idx[i..=j] {
            if labels[k] > 0.5 {
                rank_sum_pos += avg_rank;
            }
        }
        i = j + 1;
    }
    let n_pos_f = n_pos as f64;
    let n_neg_f = n_neg as f64;
    Some((rank_sum_pos - n_pos_f * (n_pos_f + 1.0) / 2.0) / (n_pos_f * n_neg_f))
}

/// Group AUC accumulator: per-user (score, label) streams.
#[derive(Clone, Debug, Default)]
pub struct GaucAccumulator {
    by_user: HashMap<u64, (Vec<f32>, Vec<f32>)>,
}

impl GaucAccumulator {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, user: u64, score: f32, label: f32) {
        let e = self.by_user.entry(user).or_default();
        e.0.push(score);
        e.1.push(label);
    }

    pub fn merge(&mut self, other: GaucAccumulator) {
        for (u, (s, l)) in other.by_user {
            let e = self.by_user.entry(u).or_default();
            e.0.extend(s);
            e.1.extend(l);
        }
    }

    pub fn users(&self) -> usize {
        self.by_user.len()
    }

    pub fn samples(&self) -> usize {
        self.by_user.values().map(|(s, _)| s.len()).sum()
    }

    /// GAUC = Σ_u w_u · AUC_u / Σ_u w_u with w_u = n_pos(u)·n_neg(u);
    /// users with a single class contribute nothing (standard practice).
    pub fn gauc(&self) -> Option<f64> {
        let mut num = 0.0;
        let mut den = 0.0;
        for (scores, labels) in self.by_user.values() {
            if let Some(a) = auc(scores, labels) {
                let p = labels.iter().filter(|&&y| y > 0.5).count() as f64;
                let n = labels.len() as f64 - p;
                let w = p * n;
                num += w * a;
                den += w;
            }
        }
        (den > 0.0).then(|| num / den)
    }

    pub fn clear(&mut self) {
        self.by_user.clear();
    }
}

/// Wall-clock throughput meter.
#[derive(Clone, Debug, Default)]
pub struct Throughput {
    pub samples: u64,
    pub tokens: u64,
    pub seconds: f64,
}

impl Throughput {
    pub fn add(&mut self, samples: u64, tokens: u64, seconds: f64) {
        self.samples += samples;
        self.tokens += tokens;
        self.seconds += seconds;
    }

    pub fn samples_per_sec(&self) -> f64 {
        self.samples as f64 / self.seconds.max(1e-12)
    }

    pub fn tokens_per_sec(&self) -> f64 {
        self.tokens as f64 / self.seconds.max(1e-12)
    }
}

/// Analytic device-time model (A100-like) for simulated step times.
///
/// The paper's testbed is A100 SXM4 80GB (312 TFLOPs bf16 peak); an
/// effective MFU around 35% is typical for HSTU-style recommendation
/// training, giving ~110 TFLOPs/s sustained. Lookup throughput models
/// the GPU hash-table path (§4.1).
#[derive(Clone, Copy, Debug)]
pub struct DeviceModel {
    /// Sustained dense FLOPs/s.
    pub flops_per_sec: f64,
    /// Hash-table lookups/s (dynamic table, grouped parallel probing).
    pub lookups_per_sec: f64,
    /// HBM bytes/s for embedding gather/scatter.
    pub hbm_bytes_per_sec: f64,
    /// Fixed per-step kernel-launch/framework overhead (seconds).
    pub step_overhead: f64,
}

impl Default for DeviceModel {
    fn default() -> Self {
        DeviceModel {
            flops_per_sec: 110.0e12,
            lookups_per_sec: 2.0e9,
            hbm_bytes_per_sec: 1.5e12,
            step_overhead: 1.0e-3,
        }
    }
}

impl DeviceModel {
    /// Compute time for one device's micro-batch: forward + backward
    /// (≈ 2× forward FLOPs; 3× total).
    pub fn compute_time(&self, forward_flops: f64) -> f64 {
        3.0 * forward_flops / self.flops_per_sec + self.step_overhead
    }

    /// Local embedding work: `lookups` table probes plus `rows × dim`
    /// f32 gather + scatter traffic.
    pub fn lookup_time(&self, lookups: usize, rows: usize, dim: usize) -> f64 {
        lookups as f64 / self.lookups_per_sec
            + 2.0 * (rows * dim * 4) as f64 / self.hbm_bytes_per_sec
    }
}

/// Split a communication phase into `(exposed, hidden)` seconds given
/// the compute it can overlap with. With `overlap` on, the exchange
/// proceeds concurrently with compute (posted isend/irecv), exposing
/// only the excess beyond the compute window; off, the whole exchange
/// is serial and exposed. Drives the Fig. 12-style step decomposition
/// for the trainer and the scale simulator.
pub fn overlap_exposure(compute_s: f64, comm_s: f64, overlap: bool) -> (f64, f64) {
    if overlap {
        let exposed = (comm_s - compute_s).max(0.0);
        (exposed, comm_s - exposed)
    } else {
        (comm_s, 0.0)
    }
}

/// Lane-aware overlap split: several communication lanes (ID exchange,
/// embedding reply, backward gradients — the double-buffered pipeline)
/// share one compute window in priority order. Each lane hides up to
/// the window *remaining* after the lanes before it; returns per-lane
/// `(exposed, hidden)` in input order. With `overlap` off everything is
/// exposed. Conservation holds per lane: `exposed + hidden == lane`.
pub fn overlap_exposure_lanes(window_s: f64, lanes: &[f64], overlap: bool) -> Vec<(f64, f64)> {
    let mut remaining = if overlap { window_s } else { 0.0 };
    lanes
        .iter()
        .map(|&comm| {
            let (exposed, hidden) = overlap_exposure(remaining, comm, overlap);
            remaining = (remaining - hidden).max(0.0);
            (exposed, hidden)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn auc_perfect_and_inverted() {
        let labels = [0.0, 0.0, 1.0, 1.0];
        assert_eq!(auc(&[0.1, 0.2, 0.8, 0.9], &labels), Some(1.0));
        assert_eq!(auc(&[0.9, 0.8, 0.2, 0.1], &labels), Some(0.0));
        assert_eq!(auc(&[0.5, 0.5, 0.5, 0.5], &labels), Some(0.5));
    }

    #[test]
    fn auc_single_class_none() {
        assert_eq!(auc(&[0.1, 0.2], &[1.0, 1.0]), None);
        assert_eq!(auc(&[0.1, 0.2], &[0.0, 0.0]), None);
    }

    #[test]
    fn auc_matches_pair_counting() {
        // AUC == P(score_pos > score_neg) + 0.5 P(tie), brute force.
        let mut rng = crate::util::rng::Xoshiro256::new(12);
        for _ in 0..50 {
            let n = rng.range_usize(5, 40);
            let scores: Vec<f32> =
                (0..n).map(|_| (rng.gen_range(10) as f32) / 10.0).collect();
            let labels: Vec<f32> = (0..n).map(|_| rng.gen_range(2) as f32).collect();
            let Some(a) = auc(&scores, &labels) else { continue };
            let mut num = 0.0;
            let mut den = 0.0;
            for i in 0..n {
                for j in 0..n {
                    if labels[i] > 0.5 && labels[j] < 0.5 {
                        den += 1.0;
                        if scores[i] > scores[j] {
                            num += 1.0;
                        } else if scores[i] == scores[j] {
                            num += 0.5;
                        }
                    }
                }
            }
            assert!((a - num / den).abs() < 1e-9, "{a} vs {}", num / den);
        }
    }

    #[test]
    fn gauc_groups_by_user() {
        let mut g = GaucAccumulator::new();
        // User 1: perfectly ranked. User 2: inverted. Equal weights.
        for (s, l) in [(0.9, 1.0), (0.1, 0.0)] {
            g.add(1, s, l);
        }
        for (s, l) in [(0.1, 1.0), (0.9, 0.0)] {
            g.add(2, s, l);
        }
        assert_eq!(g.gauc(), Some(0.5));
        assert_eq!(g.users(), 2);
        assert_eq!(g.samples(), 4);
        // Global AUC over the pooled data would also be 0.5 here, but
        // with asymmetric users GAUC differs — weight check:
        let mut g2 = GaucAccumulator::new();
        // User A: 2 pos, 1 neg ranked perfectly → w = 2, auc 1.
        g2.add(10, 0.9, 1.0);
        g2.add(10, 0.8, 1.0);
        g2.add(10, 0.1, 0.0);
        // User B: 1 pos, 1 neg inverted → w = 1, auc 0.
        g2.add(20, 0.1, 1.0);
        g2.add(20, 0.9, 0.0);
        let got = g2.gauc().unwrap();
        assert!((got - 2.0 / 3.0).abs() < 1e-9, "{got}");
    }

    #[test]
    fn gauc_merge_equivalent_to_single_stream() {
        let mut rng = crate::util::rng::Xoshiro256::new(5);
        let mut single = GaucAccumulator::new();
        let mut a = GaucAccumulator::new();
        let mut b = GaucAccumulator::new();
        for i in 0..500 {
            let user = rng.gen_range(20);
            let score = rng.next_f32();
            let label = rng.gen_range(2) as f32;
            single.add(user, score, label);
            if i % 2 == 0 {
                a.add(user, score, label);
            } else {
                b.add(user, score, label);
            }
        }
        a.merge(b);
        assert_eq!(a.gauc(), single.gauc());
    }

    #[test]
    fn throughput_meter() {
        let mut t = Throughput::default();
        t.add(100, 60_000, 2.0);
        t.add(100, 60_000, 2.0);
        assert!((t.samples_per_sec() - 50.0).abs() < 1e-9);
        assert!((t.tokens_per_sec() - 30_000.0).abs() < 1e-9);
    }

    #[test]
    fn overlap_exposure_splits_correctly() {
        // Fully hidden: comm fits inside compute.
        assert_eq!(overlap_exposure(10.0, 3.0, true), (0.0, 3.0));
        // Partially hidden: only the excess is exposed.
        assert_eq!(overlap_exposure(2.0, 5.0, true), (3.0, 2.0));
        // Overlap off: everything exposed, nothing hidden.
        assert_eq!(overlap_exposure(10.0, 3.0, false), (3.0, 0.0));
        // Conservation: exposed + hidden == comm.
        for &(c, m, o) in &[(1.0, 4.0, true), (4.0, 1.0, true), (2.0, 2.0, false)] {
            let (e, h) = overlap_exposure(c, m, o);
            assert!((e + h - m).abs() < 1e-12);
        }
    }

    #[test]
    fn lane_exposure_priority_and_conservation() {
        // Window 5 over lanes [2, 2, 2]: first two hide fully, third
        // hides the remaining 1 and exposes 1.
        let shares = overlap_exposure_lanes(5.0, &[2.0, 2.0, 2.0], true);
        assert_eq!(shares, vec![(0.0, 2.0), (0.0, 2.0), (1.0, 1.0)]);
        // Conservation per lane.
        for (i, &(e, h)) in shares.iter().enumerate() {
            assert!((e + h - 2.0).abs() < 1e-12, "lane {i}");
        }
        // Overlap off: everything exposed.
        let off = overlap_exposure_lanes(5.0, &[2.0, 3.0], false);
        assert_eq!(off, vec![(2.0, 0.0), (3.0, 0.0)]);
        // Empty window: nothing hides.
        let none = overlap_exposure_lanes(0.0, &[1.0], true);
        assert_eq!(none, vec![(1.0, 0.0)]);
    }

    #[test]
    fn device_model_scales() {
        let m = DeviceModel::default();
        let t1 = m.compute_time(1e12);
        let t2 = m.compute_time(2e12);
        assert!(t2 > t1);
        // 1 TFLOP fwd ≈ 3/110e12 s + 1 ms ≈ 28.3 ms.
        assert!((t1 - (3.0 / 110.0 + 1.0e-3)).abs() < 1e-3);
        assert!(m.lookup_time(1_000_000, 100_000, 64) > 0.0);
    }
}
