//! Dynamic sequence balancing (§5.1, Algorithm 1).
//!
//! User sequences are long-tailed; fixed-size batches give different
//! devices wildly different token counts (the paper measures spreads up
//! to 40 000 tokens and 25.8 ms of idle time per step on 8 GPUs, Fig. 9).
//! GRMs cannot truncate or pad (accuracy), so MTGRBoost instead varies
//! the *number of sequences* per device so every device carries ≈ N
//! tokens (N = average length × batch size).
//!
//! [`DynamicBatcher`] implements Algorithm 1: a per-device buffer `Q` is
//! filled from input chunks; cumulative token counts `S` are computed and
//! a binary search finds the cut `k` whose cumulative sum is closest to
//! the target `N`; the first `k` sequences pop as the balanced batch and
//! the remainder carries over. [`FixedBatcher`] is the baseline.
//!
//! Because devices now hold different numbers of samples, plain gradient
//! averaging is biased; [`weighted_scale`] implements the paper's fix
//! (all-gather batch sizes, weight gradients proportionally).

use crate::data::schema::Sequence;

/// A balanced batch plus batching statistics.
#[derive(Clone, Debug)]
pub struct Batch {
    pub sequences: Vec<Sequence>,
    /// Total real tokens in the batch.
    pub tokens: usize,
}

impl Batch {
    pub fn batch_size(&self) -> usize {
        self.sequences.len()
    }
}

/// Common interface over the dynamic batcher and the fixed baseline.
pub trait Batcher {
    /// Feed a chunk of sequences (from the shard reader / generator).
    fn push_chunk(&mut self, chunk: Vec<Sequence>);

    /// Try to emit the next batch. `None` means "need more input".
    fn next_batch(&mut self) -> Option<Batch>;

    /// Flush whatever remains (end of data).
    fn flush(&mut self) -> Option<Batch>;

    /// Sequences currently buffered.
    fn buffered(&self) -> usize;

    /// Tokens currently buffered (the carry-over the last emission left
    /// behind) — scenario telemetry. Batchers that don't track token
    /// counts may report 0.
    fn queued_tokens(&self) -> usize {
        0
    }
}

/// Algorithm 1: dynamic sequence batching.
pub struct DynamicBatcher {
    /// Target token count N (avg seq length × batch size).
    pub target_tokens: usize,
    queue: std::collections::VecDeque<Sequence>,
    queued_tokens: usize,
}

impl DynamicBatcher {
    pub fn new(target_tokens: usize) -> Self {
        assert!(target_tokens > 0);
        DynamicBatcher {
            target_tokens,
            queue: std::collections::VecDeque::new(),
            queued_tokens: 0,
        }
    }

    /// The partition point: smallest k whose cumulative sum is *closest*
    /// to N (binary search over the cumulative sums, per Algorithm 1).
    /// Returns k ≥ 1 (at least one sequence, so oversized single
    /// sequences still make progress).
    fn partition_point(&self) -> usize {
        let mut cumsum = Vec::with_capacity(self.queue.len());
        let mut acc = 0usize;
        for s in &self.queue {
            acc += s.len();
            cumsum.push(acc);
        }
        let n = self.target_tokens;
        // Binary search for the first cumulative sum ≥ N.
        let idx = cumsum.partition_point(|&c| c < n);
        if idx == 0 {
            return 1; // first sequence alone exceeds N
        }
        if idx >= cumsum.len() {
            return cumsum.len();
        }
        // Choose the closer of cumsum[idx-1] (< N) and cumsum[idx] (≥ N).
        let below = n - cumsum[idx - 1];
        let above = cumsum[idx] - n;
        if below <= above {
            idx
        } else {
            idx + 1
        }
    }
}

impl Batcher for DynamicBatcher {
    fn push_chunk(&mut self, chunk: Vec<Sequence>) {
        for s in chunk {
            self.queued_tokens += s.len();
            self.queue.push_back(s);
        }
    }

    fn next_batch(&mut self) -> Option<Batch> {
        // Algorithm 1: only emit when the buffer holds ≥ N tokens, so the
        // emitted batch can actually reach the target (otherwise keep
        // accumulating chunks).
        if self.queued_tokens < self.target_tokens {
            return None;
        }
        let k = self.partition_point();
        let mut sequences = Vec::with_capacity(k);
        let mut tokens = 0usize;
        for _ in 0..k {
            let s = self.queue.pop_front().unwrap();
            tokens += s.len();
            sequences.push(s);
        }
        self.queued_tokens -= tokens;
        Some(Batch { sequences, tokens })
    }

    fn flush(&mut self) -> Option<Batch> {
        if self.queue.is_empty() {
            return None;
        }
        let sequences: Vec<Sequence> = self.queue.drain(..).collect();
        let tokens = sequences.iter().map(|s| s.len()).sum();
        self.queued_tokens = 0;
        Some(Batch { sequences, tokens })
    }

    fn buffered(&self) -> usize {
        self.queue.len()
    }

    fn queued_tokens(&self) -> usize {
        self.queued_tokens
    }
}

/// Baseline: fixed number of sequences per batch (token count varies —
/// the source of Fig. 9's imbalance).
pub struct FixedBatcher {
    pub batch_size: usize,
    queue: std::collections::VecDeque<Sequence>,
}

impl FixedBatcher {
    pub fn new(batch_size: usize) -> Self {
        assert!(batch_size > 0);
        FixedBatcher {
            batch_size,
            queue: std::collections::VecDeque::new(),
        }
    }
}

impl Batcher for FixedBatcher {
    fn push_chunk(&mut self, chunk: Vec<Sequence>) {
        self.queue.extend(chunk);
    }

    fn next_batch(&mut self) -> Option<Batch> {
        if self.queue.len() < self.batch_size {
            return None;
        }
        let sequences: Vec<Sequence> = self.queue.drain(..self.batch_size).collect();
        let tokens = sequences.iter().map(|s| s.len()).sum();
        Some(Batch { sequences, tokens })
    }

    fn flush(&mut self) -> Option<Batch> {
        if self.queue.is_empty() {
            return None;
        }
        let sequences: Vec<Sequence> = self.queue.drain(..).collect();
        let tokens = sequences.iter().map(|s| s.len()).sum();
        Some(Batch { sequences, tokens })
    }

    fn buffered(&self) -> usize {
        self.queue.len()
    }
}

/// Weighted gradient averaging for dynamic batch sizes (§5.1):
/// after all-gathering every device's sample count, scale the local
/// gradient *sum* by `1 / total_samples` so the all-reduced sum equals
/// the true global mean gradient.
pub fn weighted_scale(local_samples: u64, all_samples: &[u64]) -> f32 {
    let total: u64 = all_samples.iter().sum();
    assert!(total > 0, "no samples in step");
    let _ = local_samples;
    1.0 / total as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generator::{GeneratorConfig, WorkloadGenerator};
    use crate::data::schema::Schema;

    fn seqs_of_lens(lens: &[usize]) -> Vec<Sequence> {
        lens.iter()
            .map(|&l| Sequence {
                user_id: l as u64,
                context: vec![0, 0, 0],
                tokens: vec![vec![0, 0, 0, 0]; l],
                labels: [0.0, 0.0],
            })
            .collect()
    }

    #[test]
    fn emits_near_target_batches() {
        let mut b = DynamicBatcher::new(100);
        b.push_chunk(seqs_of_lens(&[30, 30, 30, 30, 30, 30, 30]));
        let batch = b.next_batch().unwrap();
        // cumsum: 30,60,90,120 → 90 (dist 10) vs 120 (dist 20) → k=3.
        assert_eq!(batch.batch_size(), 3);
        assert_eq!(batch.tokens, 90);
    }

    #[test]
    fn prefers_closest_above_when_nearer() {
        let mut b = DynamicBatcher::new(100);
        b.push_chunk(seqs_of_lens(&[95, 95, 95]));
        let batch = b.next_batch().unwrap();
        // cumsum: 95,190 → |95-100|=5 < |190-100|=90 → k=1.
        assert_eq!(batch.batch_size(), 1);
        assert_eq!(batch.tokens, 95);

        let mut b = DynamicBatcher::new(100);
        b.push_chunk(seqs_of_lens(&[60, 45, 60, 60]));
        let batch = b.next_batch().unwrap();
        // cumsum: 60,105,... → |60-100|=40 > |105-100|=5 → k=2.
        assert_eq!(batch.tokens, 105);
    }

    #[test]
    fn oversized_single_sequence_progresses() {
        let mut b = DynamicBatcher::new(100);
        b.push_chunk(seqs_of_lens(&[500, 10]));
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.batch_size(), 1);
        assert_eq!(batch.tokens, 500);
    }

    #[test]
    fn waits_for_enough_tokens_then_carries_over() {
        let mut b = DynamicBatcher::new(100);
        b.push_chunk(seqs_of_lens(&[40]));
        assert!(b.next_batch().is_none(), "below target: keep buffering");
        b.push_chunk(seqs_of_lens(&[40, 40]));
        // cumsum 40,80,120; first ≥100 is 120; below = 20 == above = 20 →
        // tie prefers below → k=2 → 80 tokens, one sequence carries over.
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.tokens, 80);
        assert_eq!(b.buffered(), 1);
    }

    #[test]
    fn tie_prefers_below() {
        let mut b = DynamicBatcher::new(100);
        b.push_chunk(seqs_of_lens(&[40, 40, 40]));
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.batch_size(), 2);
        assert_eq!(batch.tokens, 80);
        // Carryover: remaining one sequence of 40 tokens.
        assert_eq!(b.buffered(), 1);
        let tail = b.flush().unwrap();
        assert_eq!(tail.tokens, 40);
        assert!(b.flush().is_none());
    }

    #[test]
    fn carryover_survives_push_next_interleavings() {
        // The buffered remainder must survive arbitrary interleavings
        // of push_chunk and next_batch: every sequence comes out
        // exactly once, in order, regardless of when input arrives.
        let lens = [30usize, 80, 10, 95, 40, 40, 40, 5, 120, 60, 25, 35];
        let expected_users: Vec<u64> = lens.iter().map(|&l| l as u64).collect();
        // Interleaving A: one big push, drain fully.
        // Interleaving B: push one sequence at a time, draining eagerly
        // after every push (next_batch interleaved with push_chunk).
        let mut eager = DynamicBatcher::new(100);
        let mut eager_users = Vec::new();
        for &l in &lens {
            eager.push_chunk(seqs_of_lens(&[l]));
            while let Some(b) = eager.next_batch() {
                eager_users.extend(b.sequences.iter().map(|s| s.user_id));
            }
        }
        if let Some(b) = eager.flush() {
            eager_users.extend(b.sequences.iter().map(|s| s.user_id));
        }
        assert_eq!(eager_users, expected_users, "eager drain loses/dups/reorders");
        assert_eq!(eager.buffered(), 0);

        // Interleaving C: pushes of 3, draining only every other push.
        let mut lazy = DynamicBatcher::new(100);
        let mut lazy_users = Vec::new();
        for (i, chunk) in lens.chunks(3).enumerate() {
            lazy.push_chunk(seqs_of_lens(chunk));
            if i % 2 == 1 {
                while let Some(b) = lazy.next_batch() {
                    lazy_users.extend(b.sequences.iter().map(|s| s.user_id));
                }
            }
        }
        while let Some(b) = lazy.next_batch() {
            lazy_users.extend(b.sequences.iter().map(|s| s.user_id));
        }
        if let Some(b) = lazy.flush() {
            lazy_users.extend(b.sequences.iter().map(|s| s.user_id));
        }
        assert_eq!(lazy_users, expected_users, "lazy drain loses/dups/reorders");
    }

    #[test]
    fn flush_emits_exactly_the_leftover() {
        let mut b = DynamicBatcher::new(100);
        b.push_chunk(seqs_of_lens(&[60, 45, 20, 15]));
        let first = b.next_batch().unwrap();
        // cumsum 60,105,... → k=2 (105 closer to 100 than 60).
        assert_eq!(first.tokens, 105);
        assert_eq!(b.buffered(), 2);
        // Below target now: next_batch holds, flush drains exactly the
        // remainder — no loss, no duplication.
        assert!(b.next_batch().is_none());
        let tail = b.flush().unwrap();
        let tail_users: Vec<u64> = tail.sequences.iter().map(|s| s.user_id).collect();
        assert_eq!(tail_users, vec![20, 15]);
        assert_eq!(tail.tokens, 35);
        assert_eq!(b.buffered(), 0);
        assert!(b.flush().is_none(), "second flush must be empty");
    }

    #[test]
    fn single_long_sequence_over_target_carries_over_cleanly() {
        // The pathological case: one sequence alone exceeds the target.
        // It must emit alone (progress), and the buffered remainder must
        // survive intact around it.
        let mut b = DynamicBatcher::new(100);
        b.push_chunk(seqs_of_lens(&[40, 500, 10]));
        // cumsum 40,540,550: first ≥100 is idx 1; 40 (dist 60) beats
        // 540 (dist 440) → k=1: the short head emits first.
        let first = b.next_batch().unwrap();
        assert_eq!(first.tokens, 40);
        // Now the oversized sequence heads the queue: emits alone.
        let second = b.next_batch().unwrap();
        assert_eq!(second.batch_size(), 1);
        assert_eq!(second.tokens, 500);
        // Remainder below target: held for more input, then flushed.
        assert!(b.next_batch().is_none());
        let tail = b.flush().unwrap();
        assert_eq!(tail.tokens, 10);
        assert_eq!(b.buffered(), 0);
    }

    #[test]
    fn conservation_no_sample_lost_or_duplicated() {
        let schema = Schema::meituan_like(8, 1);
        let mut gen = WorkloadGenerator::new(GeneratorConfig::default());
        let all = gen.batch(&schema, 300);
        let all_users: Vec<u64> = all.iter().map(|s| s.user_id).collect();
        let total_tokens: usize = all.iter().map(|s| s.len()).sum();

        let mut b = DynamicBatcher::new(50_000);
        let mut seen_users = Vec::new();
        let mut seen_tokens = 0usize;
        for chunk in all.chunks(37) {
            b.push_chunk(chunk.to_vec());
            while let Some(batch) = b.next_batch() {
                seen_tokens += batch.tokens;
                seen_users.extend(batch.sequences.iter().map(|s| s.user_id));
            }
        }
        if let Some(batch) = b.flush() {
            seen_tokens += batch.tokens;
            seen_users.extend(batch.sequences.iter().map(|s| s.user_id));
        }
        assert_eq!(seen_tokens, total_tokens);
        assert_eq!(seen_users, all_users, "order-preserving, no loss/dup");
    }

    #[test]
    fn balanced_variance_much_lower_than_fixed() {
        // The Fig. 15 effect: token-count spread across emitted batches
        // collapses under dynamic batching.
        let schema = Schema::meituan_like(8, 1);
        let mut gen = WorkloadGenerator::new(GeneratorConfig::default());
        let all = gen.batch(&schema, 2000);
        let avg_len: usize =
            all.iter().map(|s| s.len()).sum::<usize>() / all.len();
        let bs = 32usize;
        let target = avg_len * bs;

        let mut dynb = DynamicBatcher::new(target);
        let mut fixb = FixedBatcher::new(bs);
        let mut dyn_tokens = Vec::new();
        let mut fix_tokens = Vec::new();
        for chunk in all.chunks(64) {
            dynb.push_chunk(chunk.to_vec());
            fixb.push_chunk(chunk.to_vec());
            while let Some(b) = dynb.next_batch() {
                dyn_tokens.push(b.tokens as f64);
            }
            while let Some(b) = fixb.next_batch() {
                fix_tokens.push(b.tokens as f64);
            }
        }
        let d = crate::util::stats::Summary::of(&dyn_tokens);
        let f = crate::util::stats::Summary::of(&fix_tokens);
        assert!(
            d.std < f.std / 4.0,
            "dynamic std {:.0} vs fixed std {:.0}",
            d.std,
            f.std
        );
        // Mean lands near the target.
        let rel = (d.mean - target as f64).abs() / (target as f64);
        assert!(rel < 0.05, "mean off target by {rel:.3}");
    }

    #[test]
    fn extreme_skew_never_overshoots_past_the_last_sequence() {
        // The skew-storm shape: length-1 stubs interleaved with
        // cap-length monsters. Invariant of Algorithm 1's cut: a batch
        // may exceed the target only by (part of) its LAST sequence —
        // dropping that sequence always lands strictly below N. Plus
        // full conservation: nothing lost, nothing duplicated.
        let lens: Vec<usize> = (0..400)
            .map(|i| match i % 7 {
                0 => 3000,
                1 => 1,
                2 => 2,
                3 => 1500,
                4 => 1,
                5 => 700,
                _ => 3,
            })
            .collect();
        let total: usize = lens.iter().sum();
        let target = 2048usize;
        let mut b = DynamicBatcher::new(target);
        let mut seen_tokens = 0usize;
        let mut seen_seqs = 0usize;
        let mut emitted = 0usize;
        for chunk in lens.chunks(13) {
            b.push_chunk(seqs_of_lens(chunk));
            while let Some(batch) = b.next_batch() {
                emitted += 1;
                seen_tokens += batch.tokens;
                seen_seqs += batch.batch_size();
                let last = batch.sequences.last().unwrap().len();
                assert!(
                    batch.tokens - last < target,
                    "batch of {} tokens overshot by more than its last \
                     sequence ({last})",
                    batch.tokens
                );
                // Emission accounting stays consistent under skew.
                assert_eq!(
                    batch.tokens,
                    batch.sequences.iter().map(|s| s.len()).sum::<usize>()
                );
            }
        }
        if let Some(tail) = b.flush() {
            assert!(tail.tokens < target, "flush only holds sub-target residue");
            seen_tokens += tail.tokens;
            seen_seqs += tail.batch_size();
        }
        assert_eq!(seen_tokens, total, "token conservation under skew");
        assert_eq!(seen_seqs, lens.len(), "sequence conservation under skew");
        assert!(emitted > 50, "the storm actually produced many batches");
        assert_eq!(b.buffered(), 0);
        assert_eq!(b.queued_tokens(), 0);
    }

    #[test]
    fn adversarial_carryover_boundary_cases() {
        // Exact-target hit leaves zero carry-over.
        let mut b = DynamicBatcher::new(100);
        b.push_chunk(seqs_of_lens(&[100]));
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.tokens, 100);
        assert_eq!(b.queued_tokens(), 0);
        // A monster right behind an exact hit emits alone; the stub
        // behind it is held (below target), never dropped.
        b.push_chunk(seqs_of_lens(&[100, 3000, 1]));
        assert_eq!(b.next_batch().unwrap().tokens, 100);
        let monster = b.next_batch().unwrap();
        assert_eq!(monster.batch_size(), 1);
        assert_eq!(monster.tokens, 3000);
        assert!(b.next_batch().is_none(), "1-token residue keeps buffering");
        assert_eq!(b.queued_tokens(), 1);
        assert_eq!(b.flush().unwrap().tokens, 1);

        // Back-to-back monsters: each emits alone, in order.
        let mut b = DynamicBatcher::new(100);
        b.push_chunk(seqs_of_lens(&[500, 600, 700]));
        for expect in [500usize, 600, 700] {
            let batch = b.next_batch().unwrap();
            assert_eq!(batch.batch_size(), 1);
            assert_eq!(batch.tokens, expect);
        }
        assert!(b.flush().is_none());

        // All-stubs storm: thousands of length-1 sequences pack to
        // exactly the target, remainder flushes intact.
        let mut b = DynamicBatcher::new(64);
        b.push_chunk(seqs_of_lens(&vec![1usize; 1000]));
        let mut seen = 0usize;
        while let Some(batch) = b.next_batch() {
            assert_eq!(batch.tokens, 64, "stubs pack to exactly N");
            seen += batch.tokens;
        }
        assert_eq!(b.queued_tokens(), 1000 - seen);
        seen += b.flush().map_or(0, |t| t.tokens);
        assert_eq!(seen, 1000);
    }

    #[test]
    fn queued_tokens_tracks_carryover() {
        let mut b = DynamicBatcher::new(100);
        assert_eq!(b.queued_tokens(), 0);
        b.push_chunk(seqs_of_lens(&[40, 40, 40]));
        assert_eq!(b.queued_tokens(), 120);
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.tokens, 80);
        assert_eq!(b.queued_tokens(), 40, "carry-over after the cut");
        b.flush();
        assert_eq!(b.queued_tokens(), 0);
        // The fixed baseline reports 0 (doesn't track tokens).
        let f = FixedBatcher::new(4);
        assert_eq!(Batcher::queued_tokens(&f), 0);
    }

    #[test]
    fn fixed_batcher_counts() {
        let mut b = FixedBatcher::new(3);
        b.push_chunk(seqs_of_lens(&[1, 2, 3, 4]));
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.batch_size(), 3);
        assert_eq!(batch.tokens, 6);
        assert!(b.next_batch().is_none());
        assert_eq!(b.flush().unwrap().batch_size(), 1);
    }

    #[test]
    fn weighted_scale_unbiased() {
        // Sum over devices of (local_sum × scale) must equal global mean:
        // scale = 1/total regardless of local size.
        let sizes = [500u64, 200, 300];
        for &s in &sizes {
            assert_eq!(weighted_scale(s, &sizes), 1.0 / 1000.0);
        }
    }
}
