//! Deterministic pure-Rust reference executor — the CPU stand-in for
//! the AOT train/forward artifacts.
//!
//! The real artifacts run the HSTU+MMoE stack through PJRT; offline (no
//! `xla` bindings, no compiled HLO) we still need the *system* — the
//! distributed trainer, sharded embedding exchange, optimizers and
//! checkpointing — to execute end to end and bit-reproducibly. This
//! module implements a minimal differentiable head with the exact
//! artifact contract:
//!
//! ```text
//! train:   (params, emb[B,L,D], lengths[B], labels[B,T])
//!        → (loss_sums[T], grads[P], emb_grad[B,L,D], logits[B,T], n_valid)
//! forward: (params, emb, lengths) → (logits[B,T],)
//! ```
//!
//! Model: per-sequence masked mean-pool over the valid positions, then
//! one linear head per task on the first `T·(D+1)` parameters, with
//! binary cross-entropy losses. Gradients are analytic (verified by a
//! finite-difference test below) and flow to both the head parameters
//! and the embedding input, so sparse rows genuinely train. Every
//! operation is fixed-order `f32` arithmetic: two runs with identical
//! inputs produce bit-identical outputs, which the e2e determinism
//! suite relies on.

use anyhow::{bail, ensure, Result};

use super::engine::Tensor;
use super::manifest::{ArtifactKind, ModelArtifacts};

#[inline]
fn sigmoid(z: f32) -> f32 {
    1.0 / (1.0 + (-z).exp())
}

/// Numerically stable `ln(1 + e^z)`.
#[inline]
fn softplus(z: f32) -> f32 {
    if z > 0.0 {
        z + (-z).exp().ln_1p()
    } else {
        z.exp().ln_1p()
    }
}

/// Execute one request against the reference model.
pub fn execute(
    arts: &ModelArtifacts,
    kind: ArtifactKind,
    bucket: (usize, usize),
    inputs: &[Tensor],
) -> Result<Vec<Tensor>> {
    let (b, l) = bucket;
    let d = arts.emb_dim;
    let t = arts.tasks;
    let p = arts.param_count;
    ensure!(
        p >= t * (d + 1),
        "reference model needs {} head params, manifest says {p}",
        t * (d + 1)
    );
    let want = match kind {
        ArtifactKind::Train => 4,
        ArtifactKind::Forward => 3,
    };
    ensure!(inputs.len() == want, "expected {want} inputs, got {}", inputs.len());

    let params = inputs[0].as_f32()?;
    ensure!(params.len() == p, "params arity: {} vs {p}", params.len());
    let emb = inputs[1].as_f32()?;
    ensure!(emb.len() == b * l * d, "emb arity: {} vs {}", emb.len(), b * l * d);
    let lengths = match &inputs[2] {
        Tensor::I32 { data, .. } => data.as_slice(),
        _ => bail!("lengths tensor is not i32"),
    };
    ensure!(lengths.len() == b, "lengths arity: {} vs {b}", lengths.len());

    // ---- masked mean-pool per sequence ------------------------------
    let mut pool = vec![0.0f32; b * d];
    let mut valid_len = vec![0usize; b];
    for i in 0..b {
        let len = lengths[i].clamp(0, l as i32) as usize;
        valid_len[i] = len;
        if len == 0 {
            continue;
        }
        let acc = &mut pool[i * d..(i + 1) * d];
        for pos in 0..len {
            let row = &emb[(i * l + pos) * d..(i * l + pos + 1) * d];
            for (a, x) in acc.iter_mut().zip(row) {
                *a += x;
            }
        }
        let inv = 1.0 / len as f32;
        for a in acc.iter_mut() {
            *a *= inv;
        }
    }

    // ---- linear heads ------------------------------------------------
    // Head layout: task k owns params[k·(D+1) .. k·(D+1)+D] as weights
    // plus params[k·(D+1)+D] as bias.
    let mut logits = vec![0.0f32; b * t];
    for i in 0..b {
        for k in 0..t {
            let off = k * (d + 1);
            let w = &params[off..off + d];
            let mut z = params[off + d];
            for j in 0..d {
                z += w[j] * pool[i * d + j];
            }
            logits[i * t + k] = z;
        }
    }

    if kind == ArtifactKind::Forward {
        return Ok(vec![Tensor::f32(&[b, t], logits)]);
    }

    let labels = inputs[3].as_f32()?;
    ensure!(labels.len() == b * t, "labels arity: {} vs {}", labels.len(), b * t);

    // ---- loss + analytic backward over valid samples -----------------
    let mut loss_sums = vec![0.0f32; t];
    let mut dz = vec![0.0f32; b * t];
    let mut n_valid = 0.0f32;
    for i in 0..b {
        if valid_len[i] == 0 {
            continue;
        }
        n_valid += 1.0;
        for k in 0..t {
            let z = logits[i * t + k];
            let y = labels[i * t + k];
            loss_sums[k] += softplus(z) - y * z;
            dz[i * t + k] = sigmoid(z) - y;
        }
    }

    let mut grads = vec![0.0f32; p];
    for i in 0..b {
        if valid_len[i] == 0 {
            continue;
        }
        for k in 0..t {
            let g = dz[i * t + k];
            let off = k * (d + 1);
            for j in 0..d {
                grads[off + j] += g * pool[i * d + j];
            }
            grads[off + d] += g;
        }
    }

    // d loss / d emb[i, pos, :] = Σ_k dz[i,k] · w_k / len_i for valid
    // positions; exactly zero on padding (the contract the trainer's
    // scatter relies on).
    let mut emb_grad = vec![0.0f32; b * l * d];
    let mut gvec = vec![0.0f32; d];
    for i in 0..b {
        let len = valid_len[i];
        if len == 0 {
            continue;
        }
        gvec.fill(0.0);
        let inv = 1.0 / len as f32;
        for k in 0..t {
            let w = &params[k * (d + 1)..k * (d + 1) + d];
            let g = dz[i * t + k] * inv;
            for j in 0..d {
                gvec[j] += g * w[j];
            }
        }
        for pos in 0..len {
            emb_grad[(i * l + pos) * d..(i * l + pos + 1) * d].copy_from_slice(&gvec);
        }
    }

    Ok(vec![
        Tensor::f32(&[t], loss_sums),
        Tensor::f32(&[p], grads),
        Tensor::f32(&[b, l, d], emb_grad),
        Tensor::f32(&[b, t], logits),
        Tensor::scalar_f32(n_valid),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Bucket;
    use crate::util::rng::Xoshiro256;

    const B: usize = 3;
    const L: usize = 4;
    const D: usize = 2;
    const T: usize = 2;
    const P: usize = 10; // ≥ T·(D+1) = 6

    fn arts() -> ModelArtifacts {
        ModelArtifacts {
            name: "ref-test".into(),
            emb_dim: D,
            heads: 1,
            blocks: 1,
            tasks: T,
            param_count: P,
            params_bin: "<builtin>".into(),
            params_seed: 0,
            buckets: vec![Bucket {
                batch: B,
                len: L,
                train: "<builtin>".into(),
                forward: "<builtin>".into(),
            }],
        }
    }

    fn inputs(seed: u64) -> Vec<Tensor> {
        let mut rng = Xoshiro256::new(seed);
        let params: Vec<f32> = (0..P).map(|_| rng.normal(0.0, 0.5) as f32).collect();
        let emb: Vec<f32> = (0..B * L * D).map(|_| rng.normal(0.0, 1.0) as f32).collect();
        let lengths = vec![3, 1, 0]; // last sample padded out
        let labels: Vec<f32> = (0..B * T).map(|_| rng.gen_range(2) as f32).collect();
        vec![
            Tensor::f32(&[P], params),
            Tensor::f32(&[B, L, D], emb),
            Tensor::i32(&[B], lengths),
            Tensor::f32(&[B, T], labels),
        ]
    }

    fn total_loss(out: &[Tensor]) -> f64 {
        out[0].as_f32().unwrap().iter().map(|&x| x as f64).sum()
    }

    #[test]
    fn shapes_and_padding_contract() {
        let a = arts();
        let out = execute(&a, ArtifactKind::Train, (B, L), &inputs(1)).unwrap();
        assert_eq!(out.len(), 5);
        assert_eq!(out[0].as_f32().unwrap().len(), T);
        assert_eq!(out[1].as_f32().unwrap().len(), P);
        assert_eq!(out[2].as_f32().unwrap().len(), B * L * D);
        assert_eq!(out[3].as_f32().unwrap().len(), B * T);
        assert_eq!(out[4].as_f32().unwrap()[0], 2.0, "one padded sample");
        // Padded sample's embedding gradient is exactly zero.
        let eg = out[2].as_f32().unwrap();
        assert!(eg[(B - 1) * L * D..].iter().all(|&x| x == 0.0));
        // And so are positions past each sequence's length (len 1 → pos ≥ 1).
        assert!(eg[(1 * L + 1) * D..2 * L * D].iter().all(|&x| x == 0.0));
        // Losses positive (BCE) and finite.
        assert!(out[0].as_f32().unwrap().iter().all(|&x| x > 0.0 && x.is_finite()));
    }

    #[test]
    fn forward_matches_train_logits() {
        let a = arts();
        let ins = inputs(2);
        let train = execute(&a, ArtifactKind::Train, (B, L), &ins).unwrap();
        let fwd = execute(&a, ArtifactKind::Forward, (B, L), &ins[..3]).unwrap();
        assert_eq!(fwd[0].as_f32().unwrap(), train[3].as_f32().unwrap());
    }

    #[test]
    fn bit_identical_across_runs() {
        let a = arts();
        let ins = inputs(3);
        let o1 = execute(&a, ArtifactKind::Train, (B, L), &ins).unwrap();
        let o2 = execute(&a, ArtifactKind::Train, (B, L), &ins).unwrap();
        for (x, y) in o1.iter().zip(&o2) {
            assert_eq!(x, y);
        }
    }

    #[test]
    fn param_gradients_match_finite_differences() {
        let a = arts();
        let ins = inputs(4);
        let base = execute(&a, ArtifactKind::Train, (B, L), &ins).unwrap();
        let grads = base[1].as_f32().unwrap().to_vec();
        let l0 = total_loss(&base);
        let eps = 1e-3f32;
        for idx in 0..T * (D + 1) {
            let mut bumped = ins.clone();
            if let Tensor::F32 { data, .. } = &mut bumped[0] {
                data[idx] += eps;
            }
            let l1 = total_loss(&execute(&a, ArtifactKind::Train, (B, L), &bumped).unwrap());
            let fd = (l1 - l0) / eps as f64;
            assert!(
                (fd - grads[idx] as f64).abs() < 2e-2,
                "param {idx}: fd {fd:.4} vs analytic {:.4}",
                grads[idx]
            );
        }
        // Params beyond the head carry exactly zero gradient.
        assert!(grads[T * (D + 1)..].iter().all(|&g| g == 0.0));
    }

    #[test]
    fn emb_gradients_match_finite_differences() {
        let a = arts();
        let ins = inputs(5);
        let base = execute(&a, ArtifactKind::Train, (B, L), &ins).unwrap();
        let eg = base[2].as_f32().unwrap().to_vec();
        let l0 = total_loss(&base);
        let eps = 1e-3f32;
        // Probe a handful of valid positions.
        for &idx in &[0usize, 1, D, 2 * D + 1, (1 * L) * D] {
            let mut bumped = ins.clone();
            if let Tensor::F32 { data, .. } = &mut bumped[1] {
                data[idx] += eps;
            }
            let l1 = total_loss(&execute(&a, ArtifactKind::Train, (B, L), &bumped).unwrap());
            let fd = (l1 - l0) / eps as f64;
            assert!(
                (fd - eg[idx] as f64).abs() < 2e-2,
                "emb {idx}: fd {fd:.4} vs analytic {:.4}",
                eg[idx]
            );
        }
    }

    #[test]
    fn bad_arity_and_small_param_count_rejected() {
        let a = arts();
        assert!(execute(&a, ArtifactKind::Train, (B, L), &inputs(6)[..2]).is_err());
        let mut small = arts();
        small.param_count = 2; // < T·(D+1)
        assert!(execute(&small, ArtifactKind::Train, (B, L), &inputs(7)).is_err());
    }
}
