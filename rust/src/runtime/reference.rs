//! Deterministic pure-Rust reference executor — the CPU stand-in for
//! the AOT train/forward artifacts.
//!
//! The real artifacts run the HSTU+MMoE stack through PJRT; offline (no
//! `xla` bindings, no compiled HLO) we still need the *system* — the
//! distributed trainer, sharded embedding exchange, optimizers and
//! checkpointing — to execute end to end and bit-reproducibly. This
//! module implements a minimal differentiable head with the exact
//! artifact contract:
//!
//! ```text
//! train:   (params, emb[B,L,D], lengths[B], labels[B,T])
//!        → (loss_sums[T], grads[P], emb_grad[B,L,D], logits[B,T], n_valid)
//! forward: (params, emb, lengths) → (logits[B,T],)
//! ```
//!
//! Model: per-sequence masked mean-pool over the valid positions, then
//! one linear head per task on the first `T·(D+1)` parameters, with
//! binary cross-entropy losses. Gradients are analytic (verified by a
//! finite-difference test below) and flow to both the head parameters
//! and the embedding input, so sparse rows genuinely train.
//!
//! **Parallel, thread-count-invariant execution.** Per-sample work is
//! independent, so [`train_into`] splits the batch into a *fixed*
//! number of chunks ([`DENSE_CHUNKS`] — a pure function of the batch,
//! never of the pool size) and runs the chunks on the shared
//! [`WorkerPool`] when one is supplied. Disjoint outputs (pool, logits,
//! dz, emb_grad) are written in place; the cross-sample reductions
//! (loss sums, parameter gradients, the valid count) are accumulated
//! *per chunk* and folded in ascending chunk order afterwards. Because
//! the chunk boundaries and the fold order are fixed, every pool size —
//! including the serial `None` path, which walks the same chunks in the
//! same order — produces bit-identical results. Outputs land in a
//! caller-owned [`TrainScratch`] arena so steady-state training does no
//! per-step output allocation.

use std::ops::Range;

use anyhow::{bail, ensure, Result};

use crate::util::pool::{SharedSliceMut, WorkerPool};

use super::engine::Tensor;
use super::manifest::{ArtifactKind, ModelArtifacts};

/// Fixed batch-chunk count for the parallel dense executor. Chunk
/// boundaries — and therefore the partial-reduction fold — are a pure
/// function of the batch size and this constant, never of the pool
/// size, which is what makes results thread-count-invariant.
pub const DENSE_CHUNKS: usize = 8;

#[inline]
fn sigmoid(z: f32) -> f32 {
    1.0 / (1.0 + (-z).exp())
}

/// Numerically stable `ln(1 + e^z)`.
#[inline]
fn softplus(z: f32) -> f32 {
    if z > 0.0 {
        z + (-z).exp().ln_1p()
    } else {
        z.exp().ln_1p()
    }
}

/// Reusable output + intermediate buffers for [`train_into`]: the
/// trainer keeps one per worker so the dense step allocates nothing in
/// steady state. Public fields are the train artifact's 5-tuple.
#[derive(Debug, Default)]
pub struct TrainScratch {
    /// Per-task loss sums over valid samples (length `T`).
    pub loss_sums: Vec<f32>,
    /// Flat dense gradient (length `P`).
    pub grads: Vec<f32>,
    /// Gradient w.r.t. the embedding input (`B·L·D`).
    pub emb_grad: Vec<f32>,
    /// Logits (`B·T`).
    pub logits: Vec<f32>,
    /// Number of valid (non-padded) samples.
    pub n_valid: f32,
    // ---- internals ---------------------------------------------------
    pool: Vec<f32>,
    dz: Vec<f32>,
    chunk_loss: Vec<f32>,
    chunk_grads: Vec<f32>,
    chunk_valid: Vec<f32>,
}

impl TrainScratch {
    pub fn new() -> Self {
        TrainScratch::default()
    }
}

/// One chunk's forward + backward over samples `r` (global indices).
/// Every slice argument is the chunk's disjoint window; `loss_c`,
/// `grads_c` and `valid_c` are this chunk's private partial reductions.
#[allow(clippy::too_many_arguments)]
fn train_chunk(
    params: &[f32],
    emb: &[f32],
    lengths: &[i32],
    labels: &[f32],
    r: Range<usize>,
    l: usize,
    d: usize,
    t: usize,
    pool_c: &mut [f32],
    logits_c: &mut [f32],
    dz_c: &mut [f32],
    eg_c: &mut [f32],
    loss_c: &mut [f32],
    grads_c: &mut [f32],
    valid_c: &mut f32,
) {
    let base = r.start;
    let mut gvec = vec![0.0f32; d];
    for i in r {
        let j = i - base;
        let len = lengths[i].clamp(0, l as i32) as usize;

        // ---- masked mean-pool ---------------------------------------
        if len > 0 {
            let acc = &mut pool_c[j * d..(j + 1) * d];
            for pos in 0..len {
                let row = &emb[(i * l + pos) * d..(i * l + pos + 1) * d];
                for (a, x) in acc.iter_mut().zip(row) {
                    *a += x;
                }
            }
            let inv = 1.0 / len as f32;
            for a in acc.iter_mut() {
                *a *= inv;
            }
        }

        // ---- linear heads -------------------------------------------
        for k in 0..t {
            let off = k * (d + 1);
            let w = &params[off..off + d];
            let mut z = params[off + d];
            for jj in 0..d {
                z += w[jj] * pool_c[j * d + jj];
            }
            logits_c[j * t + k] = z;
        }
        if len == 0 {
            continue; // padded sample: logits only, zero gradients
        }
        *valid_c += 1.0;

        // ---- loss + dz ----------------------------------------------
        for k in 0..t {
            let z = logits_c[j * t + k];
            let y = labels[i * t + k];
            loss_c[k] += softplus(z) - y * z;
            dz_c[j * t + k] = sigmoid(z) - y;
        }

        // ---- head parameter gradients (chunk partials) --------------
        for k in 0..t {
            let g = dz_c[j * t + k];
            let off = k * (d + 1);
            for jj in 0..d {
                grads_c[off + jj] += g * pool_c[j * d + jj];
            }
            grads_c[off + d] += g;
        }

        // ---- embedding gradient -------------------------------------
        // d loss / d emb[i, pos, :] = Σ_k dz[i,k] · w_k / len_i on valid
        // positions; exactly zero on padding (the contract the
        // trainer's scatter relies on).
        gvec.fill(0.0);
        let inv = 1.0 / len as f32;
        for k in 0..t {
            let w = &params[k * (d + 1)..k * (d + 1) + d];
            let g = dz_c[j * t + k] * inv;
            for jj in 0..d {
                gvec[jj] += g * w[jj];
            }
        }
        for pos in 0..len {
            eg_c[(j * l + pos) * d..(j * l + pos + 1) * d].copy_from_slice(&gvec);
        }
    }
}

/// Execute one train step into `s`, chunking the batch across `pool`
/// (serial and bit-identical when `pool` is `None` or single-share).
#[allow(clippy::too_many_arguments)]
pub fn train_into(
    arts: &ModelArtifacts,
    bucket: (usize, usize),
    params: &[f32],
    emb: &[f32],
    lengths: &[i32],
    labels: &[f32],
    pool: Option<&WorkerPool>,
    s: &mut TrainScratch,
) -> Result<()> {
    let (b, l) = bucket;
    let d = arts.emb_dim;
    let t = arts.tasks;
    let p = arts.param_count;
    ensure!(
        p >= t * (d + 1),
        "reference model needs {} head params, manifest says {p}",
        t * (d + 1)
    );
    ensure!(params.len() == p, "params arity: {} vs {p}", params.len());
    ensure!(emb.len() == b * l * d, "emb arity: {} vs {}", emb.len(), b * l * d);
    ensure!(lengths.len() == b, "lengths arity: {} vs {b}", lengths.len());
    ensure!(labels.len() == b * t, "labels arity: {} vs {}", labels.len(), b * t);

    let ranges = WorkerPool::chunk_ranges(b, DENSE_CHUNKS);
    let nc = ranges.len();

    // Zero-fill (capacity is retained across steps, so no allocation in
    // steady state; zeroing is required either way).
    s.loss_sums.clear();
    s.loss_sums.resize(t, 0.0);
    s.grads.clear();
    s.grads.resize(p, 0.0);
    s.emb_grad.clear();
    s.emb_grad.resize(b * l * d, 0.0);
    s.logits.clear();
    s.logits.resize(b * t, 0.0);
    s.n_valid = 0.0;
    s.pool.clear();
    s.pool.resize(b * d, 0.0);
    s.dz.clear();
    s.dz.resize(b * t, 0.0);
    s.chunk_loss.clear();
    s.chunk_loss.resize(nc * t, 0.0);
    s.chunk_grads.clear();
    s.chunk_grads.resize(nc * p, 0.0);
    s.chunk_valid.clear();
    s.chunk_valid.resize(nc, 0.0);

    if nc > 0 {
        let pool_w = SharedSliceMut::new(&mut s.pool);
        let logits_w = SharedSliceMut::new(&mut s.logits);
        let dz_w = SharedSliceMut::new(&mut s.dz);
        let eg_w = SharedSliceMut::new(&mut s.emb_grad);
        let loss_w = SharedSliceMut::new(&mut s.chunk_loss);
        let grads_w = SharedSliceMut::new(&mut s.chunk_grads);
        let valid_w = SharedSliceMut::new(&mut s.chunk_valid);
        let run_chunk = |ci: usize, r: Range<usize>| {
            let n = r.len();
            // SAFETY: `ranges` partitions `0..b` into disjoint chunks
            // and each (ci, r) pair is handed to exactly one task, so
            // every window below is written by exactly one chunk; the
            // windows live only inside this scope.
            unsafe {
                train_chunk(
                    params,
                    emb,
                    lengths,
                    labels,
                    r.clone(),
                    l,
                    d,
                    t,
                    pool_w.slice_mut(r.start * d, n * d),
                    logits_w.slice_mut(r.start * t, n * t),
                    dz_w.slice_mut(r.start * t, n * t),
                    eg_w.slice_mut(r.start * l * d, n * l * d),
                    loss_w.slice_mut(ci * t, t),
                    grads_w.slice_mut(ci * p, p),
                    &mut valid_w.slice_mut(ci, 1)[0],
                );
            }
        };
        match pool {
            Some(pl) if pl.threads() > 1 && nc > 1 => {
                let run_chunk = &run_chunk;
                let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = ranges
                    .iter()
                    .enumerate()
                    .map(|(ci, r)| {
                        let r = r.clone();
                        Box::new(move || run_chunk(ci, r)) as Box<dyn FnOnce() + Send + '_>
                    })
                    .collect();
                pl.run_scope(tasks);
            }
            _ => {
                for (ci, r) in ranges.iter().enumerate() {
                    run_chunk(ci, r.clone());
                }
            }
        }
    }

    // Fold the per-chunk partial reductions in fixed ascending chunk
    // order — the association is identical for every pool size.
    for ci in 0..nc {
        for k in 0..t {
            s.loss_sums[k] += s.chunk_loss[ci * t + k];
        }
        for j in 0..p {
            s.grads[j] += s.chunk_grads[ci * p + j];
        }
        s.n_valid += s.chunk_valid[ci];
    }
    Ok(())
}

/// Execute one request against the reference model (serial).
pub fn execute(
    arts: &ModelArtifacts,
    kind: ArtifactKind,
    bucket: (usize, usize),
    inputs: &[Tensor],
) -> Result<Vec<Tensor>> {
    execute_with_pool(arts, kind, bucket, inputs, None)
}

/// [`execute`] with an optional worker pool for the train path's
/// batch-chunked forward/backward.
pub fn execute_with_pool(
    arts: &ModelArtifacts,
    kind: ArtifactKind,
    bucket: (usize, usize),
    inputs: &[Tensor],
    pool: Option<&WorkerPool>,
) -> Result<Vec<Tensor>> {
    let (b, l) = bucket;
    let d = arts.emb_dim;
    let t = arts.tasks;
    let want = match kind {
        ArtifactKind::Train => 4,
        ArtifactKind::Forward => 3,
    };
    ensure!(inputs.len() == want, "expected {want} inputs, got {}", inputs.len());

    let params = inputs[0].as_f32()?;
    let emb = inputs[1].as_f32()?;
    let lengths = match &inputs[2] {
        Tensor::I32 { data, .. } => data.as_slice(),
        _ => bail!("lengths tensor is not i32"),
    };

    if kind == ArtifactKind::Forward {
        let p = arts.param_count;
        ensure!(
            p >= t * (d + 1),
            "reference model needs {} head params, manifest says {p}",
            t * (d + 1)
        );
        ensure!(params.len() == p, "params arity: {} vs {p}", params.len());
        ensure!(emb.len() == b * l * d, "emb arity: {} vs {}", emb.len(), b * l * d);
        ensure!(lengths.len() == b, "lengths arity: {} vs {b}", lengths.len());
        // Per-sample arithmetic is identical to the train path (which
        // the `forward_matches_train_logits` test pins down).
        let mut logits = vec![0.0f32; b * t];
        let mut acc = vec![0.0f32; d];
        for i in 0..b {
            let len = lengths[i].clamp(0, l as i32) as usize;
            acc.fill(0.0);
            if len > 0 {
                for pos in 0..len {
                    let row = &emb[(i * l + pos) * d..(i * l + pos + 1) * d];
                    for (a, x) in acc.iter_mut().zip(row) {
                        *a += x;
                    }
                }
                let inv = 1.0 / len as f32;
                for a in acc.iter_mut() {
                    *a *= inv;
                }
            }
            for k in 0..t {
                let off = k * (d + 1);
                let w = &params[off..off + d];
                let mut z = params[off + d];
                for jj in 0..d {
                    z += w[jj] * acc[jj];
                }
                logits[i * t + k] = z;
            }
        }
        return Ok(vec![Tensor::f32(&[b, t], logits)]);
    }

    let labels = inputs[3].as_f32()?;
    let mut s = TrainScratch::new();
    train_into(arts, bucket, params, emb, lengths, labels, pool, &mut s)?;
    Ok(vec![
        Tensor::f32(&[t], std::mem::take(&mut s.loss_sums)),
        Tensor::f32(&[arts.param_count], std::mem::take(&mut s.grads)),
        Tensor::f32(&[b, l, d], std::mem::take(&mut s.emb_grad)),
        Tensor::f32(&[b, t], std::mem::take(&mut s.logits)),
        Tensor::scalar_f32(s.n_valid),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Bucket;
    use crate::util::rng::Xoshiro256;

    const B: usize = 3;
    const L: usize = 4;
    const D: usize = 2;
    const T: usize = 2;
    const P: usize = 10; // ≥ T·(D+1) = 6

    fn arts() -> ModelArtifacts {
        ModelArtifacts {
            name: "ref-test".into(),
            emb_dim: D,
            heads: 1,
            blocks: 1,
            tasks: T,
            param_count: P,
            params_bin: "<builtin>".into(),
            params_seed: 0,
            buckets: vec![Bucket {
                batch: B,
                len: L,
                train: "<builtin>".into(),
                forward: "<builtin>".into(),
            }],
        }
    }

    fn inputs(seed: u64) -> Vec<Tensor> {
        let mut rng = Xoshiro256::new(seed);
        let params: Vec<f32> = (0..P).map(|_| rng.normal(0.0, 0.5) as f32).collect();
        let emb: Vec<f32> = (0..B * L * D).map(|_| rng.normal(0.0, 1.0) as f32).collect();
        let lengths = vec![3, 1, 0]; // last sample padded out
        let labels: Vec<f32> = (0..B * T).map(|_| rng.gen_range(2) as f32).collect();
        vec![
            Tensor::f32(&[P], params),
            Tensor::f32(&[B, L, D], emb),
            Tensor::i32(&[B], lengths),
            Tensor::f32(&[B, T], labels),
        ]
    }

    fn total_loss(out: &[Tensor]) -> f64 {
        out[0].as_f32().unwrap().iter().map(|&x| x as f64).sum()
    }

    #[test]
    fn shapes_and_padding_contract() {
        let a = arts();
        let out = execute(&a, ArtifactKind::Train, (B, L), &inputs(1)).unwrap();
        assert_eq!(out.len(), 5);
        assert_eq!(out[0].as_f32().unwrap().len(), T);
        assert_eq!(out[1].as_f32().unwrap().len(), P);
        assert_eq!(out[2].as_f32().unwrap().len(), B * L * D);
        assert_eq!(out[3].as_f32().unwrap().len(), B * T);
        assert_eq!(out[4].as_f32().unwrap()[0], 2.0, "one padded sample");
        // Padded sample's embedding gradient is exactly zero.
        let eg = out[2].as_f32().unwrap();
        assert!(eg[(B - 1) * L * D..].iter().all(|&x| x == 0.0));
        // And so are positions past each sequence's length (len 1 → pos ≥ 1).
        assert!(eg[(1 * L + 1) * D..2 * L * D].iter().all(|&x| x == 0.0));
        // Losses positive (BCE) and finite.
        assert!(out[0].as_f32().unwrap().iter().all(|&x| x > 0.0 && x.is_finite()));
    }

    #[test]
    fn forward_matches_train_logits() {
        let a = arts();
        let ins = inputs(2);
        let train = execute(&a, ArtifactKind::Train, (B, L), &ins).unwrap();
        let fwd = execute(&a, ArtifactKind::Forward, (B, L), &ins[..3]).unwrap();
        assert_eq!(fwd[0].as_f32().unwrap(), train[3].as_f32().unwrap());
    }

    #[test]
    fn bit_identical_across_runs() {
        let a = arts();
        let ins = inputs(3);
        let o1 = execute(&a, ArtifactKind::Train, (B, L), &ins).unwrap();
        let o2 = execute(&a, ArtifactKind::Train, (B, L), &ins).unwrap();
        for (x, y) in o1.iter().zip(&o2) {
            assert_eq!(x, y);
        }
    }

    #[test]
    fn pooled_execution_bit_identical_for_every_pool_size() {
        // A batch wide enough that every DENSE_CHUNKS chunk is
        // non-empty and threads ≠ chunks, exercising the queue.
        let mut a = arts();
        let (b, l) = (13usize, 6usize);
        a.buckets = vec![Bucket {
            batch: b,
            len: l,
            train: "<builtin>".into(),
            forward: "<builtin>".into(),
        }];
        let mut rng = Xoshiro256::new(17);
        let params: Vec<f32> = (0..P).map(|_| rng.normal(0.0, 0.5) as f32).collect();
        let emb: Vec<f32> = (0..b * l * D).map(|_| rng.normal(0.0, 1.0) as f32).collect();
        let lengths: Vec<i32> = (0..b).map(|i| (i % (l + 1)) as i32).collect();
        let labels: Vec<f32> = (0..b * T).map(|_| rng.gen_range(2) as f32).collect();
        let ins = vec![
            Tensor::f32(&[P], params),
            Tensor::f32(&[b, l, D], emb),
            Tensor::i32(&[b], lengths),
            Tensor::f32(&[b, T], labels),
        ];
        let serial = execute(&a, ArtifactKind::Train, (b, l), &ins).unwrap();
        for threads in [1usize, 2, 3, 4] {
            let pool = WorkerPool::new(threads);
            let par =
                execute_with_pool(&a, ArtifactKind::Train, (b, l), &ins, Some(&pool)).unwrap();
            for (x, y) in serial.iter().zip(&par) {
                assert_eq!(x, y, "{threads} threads diverged");
            }
        }
    }

    #[test]
    fn scratch_reuse_matches_fresh() {
        let a = arts();
        let ins = inputs(9);
        let params = ins[0].as_f32().unwrap();
        let emb = ins[1].as_f32().unwrap();
        let lengths = match &ins[2] {
            Tensor::I32 { data, .. } => data.as_slice(),
            _ => unreachable!(),
        };
        let labels = ins[3].as_f32().unwrap();
        let mut s = TrainScratch::new();
        train_into(&a, (B, L), params, emb, lengths, labels, None, &mut s).unwrap();
        let first = (
            s.loss_sums.clone(),
            s.grads.clone(),
            s.emb_grad.clone(),
            s.logits.clone(),
            s.n_valid,
        );
        // Dirty the scratch with a different step, then re-run: stale
        // contents must not leak into the outputs.
        let other = inputs(10);
        train_into(
            &a,
            (B, L),
            other[0].as_f32().unwrap(),
            other[1].as_f32().unwrap(),
            match &other[2] {
                Tensor::I32 { data, .. } => data.as_slice(),
                _ => unreachable!(),
            },
            other[3].as_f32().unwrap(),
            None,
            &mut s,
        )
        .unwrap();
        train_into(&a, (B, L), params, emb, lengths, labels, None, &mut s).unwrap();
        assert_eq!(s.loss_sums, first.0);
        assert_eq!(s.grads, first.1);
        assert_eq!(s.emb_grad, first.2);
        assert_eq!(s.logits, first.3);
        assert_eq!(s.n_valid, first.4);
    }

    #[test]
    fn param_gradients_match_finite_differences() {
        let a = arts();
        let ins = inputs(4);
        let base = execute(&a, ArtifactKind::Train, (B, L), &ins).unwrap();
        let grads = base[1].as_f32().unwrap().to_vec();
        let l0 = total_loss(&base);
        let eps = 1e-3f32;
        for idx in 0..T * (D + 1) {
            let mut bumped = ins.clone();
            if let Tensor::F32 { data, .. } = &mut bumped[0] {
                data[idx] += eps;
            }
            let l1 = total_loss(&execute(&a, ArtifactKind::Train, (B, L), &bumped).unwrap());
            let fd = (l1 - l0) / eps as f64;
            assert!(
                (fd - grads[idx] as f64).abs() < 2e-2,
                "param {idx}: fd {fd:.4} vs analytic {:.4}",
                grads[idx]
            );
        }
        // Params beyond the head carry exactly zero gradient.
        assert!(grads[T * (D + 1)..].iter().all(|&g| g == 0.0));
    }

    #[test]
    fn emb_gradients_match_finite_differences() {
        let a = arts();
        let ins = inputs(5);
        let base = execute(&a, ArtifactKind::Train, (B, L), &ins).unwrap();
        let eg = base[2].as_f32().unwrap().to_vec();
        let l0 = total_loss(&base);
        let eps = 1e-3f32;
        // Probe a handful of valid positions.
        for &idx in &[0usize, 1, D, 2 * D + 1, (1 * L) * D] {
            let mut bumped = ins.clone();
            if let Tensor::F32 { data, .. } = &mut bumped[1] {
                data[idx] += eps;
            }
            let l1 = total_loss(&execute(&a, ArtifactKind::Train, (B, L), &bumped).unwrap());
            let fd = (l1 - l0) / eps as f64;
            assert!(
                (fd - eg[idx] as f64).abs() < 2e-2,
                "emb {idx}: fd {fd:.4} vs analytic {:.4}",
                eg[idx]
            );
        }
    }

    #[test]
    fn bad_arity_and_small_param_count_rejected() {
        let a = arts();
        assert!(execute(&a, ArtifactKind::Train, (B, L), &inputs(6)[..2]).is_err());
        let mut small = arts();
        small.param_count = 2; // < T·(D+1)
        assert!(execute(&small, ArtifactKind::Train, (B, L), &inputs(7)).is_err());
    }
}
