//! Deterministic pure-Rust reference executor — the CPU stand-in for
//! the AOT train/forward artifacts.
//!
//! The real artifacts run the HSTU+MMoE stack through PJRT; offline (no
//! `xla` bindings, no compiled HLO) we still need the *system* — the
//! distributed trainer, sharded embedding exchange, optimizers and
//! checkpointing — to execute end to end and bit-reproducibly. This
//! module implements a minimal differentiable head with the exact
//! artifact contract:
//!
//! ```text
//! train:   (params, emb[B,L,D], lengths[B], labels[B,T])
//!        → (loss_sums[T], grads[P], emb_grad[B,L,D], logits[B,T], n_valid)
//! forward: (params, emb, lengths) → (logits[B,T],)
//! ```
//!
//! Two dense architectures share the contract ([`ModelArch`] on the
//! artifacts picks one):
//!
//! - **Mean-pool** (the historical toy): per-sequence masked mean-pool
//!   over the valid positions, then one linear head per task on the
//!   first `T·(D+1)` parameters, with binary cross-entropy losses.
//! - **HSTU** (`tiny-hstu`): a stack of HSTU-style pointwise-gated
//!   attention blocks ported from `python/compile/kernels/hstu.py` —
//!   per head, `P = SiLU((Q·Kᵀ)/√dh)·causal_mask/len` (no softmax),
//!   `M = P·V`, gated `A = M ⊙ U`, residual `y = x + A·Wo` — followed
//!   by the same mean-pool + heads on the final hidden state. The
//!   backward is exact and recomputes each block's tape from its stored
//!   input (FlashAttention-style recomputation, like the Python
//!   custom-VJP), so only `blocks+1` activations are kept per sample.
//!
//! Gradients are analytic (verified by finite-difference tests below)
//! and flow to both the dense parameters and the embedding input, so
//! sparse rows genuinely train.
//!
//! **Parallel, thread-count-invariant execution.** Per-sample work is
//! independent, so [`train_into`] splits the batch into a *fixed*
//! number of chunks ([`DENSE_CHUNKS`] — a pure function of the batch,
//! never of the pool size) and runs the chunks on the shared
//! [`WorkerPool`] when one is supplied. Disjoint outputs (pool, logits,
//! dz, emb_grad) are written in place; the cross-sample reductions
//! (loss sums, parameter gradients, the valid count) are accumulated
//! *per chunk* and folded in ascending chunk order afterwards. Because
//! the chunk boundaries and the fold order are fixed, every pool size —
//! including the serial `None` path, which walks the same chunks in the
//! same order — produces bit-identical results. Outputs land in a
//! caller-owned [`TrainScratch`] arena so steady-state training does no
//! per-step output allocation.

use std::ops::Range;

use anyhow::{bail, ensure, Result};

use crate::util::pool::{SharedSliceMut, WorkerPool};

use super::engine::Tensor;
use super::manifest::{ArtifactKind, ModelArch, ModelArtifacts};

/// Fixed batch-chunk count for the parallel dense executor. Chunk
/// boundaries — and therefore the partial-reduction fold — are a pure
/// function of the batch size and this constant, never of the pool
/// size, which is what makes results thread-count-invariant.
pub const DENSE_CHUNKS: usize = 8;

#[inline]
fn sigmoid(z: f32) -> f32 {
    1.0 / (1.0 + (-z).exp())
}

/// Numerically stable `ln(1 + e^z)`.
#[inline]
fn softplus(z: f32) -> f32 {
    if z > 0.0 {
        z + (-z).exp().ln_1p()
    } else {
        z.exp().ln_1p()
    }
}

#[inline]
fn silu(z: f32) -> f32 {
    z * sigmoid(z)
}

/// d SiLU / dz = σ(z)·(1 + z·(1 − σ(z))).
#[inline]
fn dsilu(z: f32) -> f32 {
    let s = sigmoid(z);
    s * (1.0 + z * (1.0 - s))
}

/// Dense parameters consumed per HSTU block: five d×d projections
/// (`Wq Wk Wv Wu Wo` in that order) followed by `9d` reserved slots
/// (the config's per-block bias/norm budget — carried at zero gradient
/// so the parameter count matches [`crate::config::ModelConfig::dense_params`]).
pub fn hstu_block_stride(d: usize) -> usize {
    5 * d * d + 9 * d
}

/// Offset of HSTU block `b`'s parameters: the `t·(d+1)` task heads come
/// first (shared with the mean-pool layout), then one stride per block.
pub fn hstu_block_off(t: usize, d: usize, b: usize) -> usize {
    t * (d + 1) + b * hstu_block_stride(d)
}

/// Slice the five d×d projection matrices of HSTU block `b` out of the
/// flat parameter vector (layout at [`hstu_block_off`]).
fn hstu_block_weights(
    params: &[f32],
    t: usize,
    d: usize,
    b: usize,
) -> (&[f32], &[f32], &[f32], &[f32], &[f32]) {
    let off = hstu_block_off(t, d, b);
    let dd = d * d;
    (
        &params[off..off + dd],
        &params[off + dd..off + 2 * dd],
        &params[off + 2 * dd..off + 3 * dd],
        &params[off + 3 * dd..off + 4 * dd],
        &params[off + 4 * dd..off + 5 * dd],
    )
}

/// `out[p,j] = Σ_k x[p,k]·w[k·d+j]` — n×d input against a row-major
/// d×d weight, overwriting `out`.
fn matmul_nd(x: &[f32], w: &[f32], n: usize, d: usize, out: &mut [f32]) {
    for p in 0..n {
        for j in 0..d {
            let mut acc = 0.0f32;
            for kx in 0..d {
                acc += x[p * d + kx] * w[kx * d + j];
            }
            out[p * d + j] = acc;
        }
    }
}

/// `out[p,k] += Σ_j g[p,j]·w[k·d+j]` — gradient through a row-major
/// d×d weight (accumulating).
fn matmul_nd_wt(g: &[f32], w: &[f32], n: usize, d: usize, out: &mut [f32]) {
    for p in 0..n {
        for kx in 0..d {
            let mut acc = 0.0f32;
            for j in 0..d {
                acc += g[p * d + j] * w[kx * d + j];
            }
            out[p * d + kx] += acc;
        }
    }
}

/// `dw[k·d+j] += Σ_p x[p,k]·g[p,j]` — weight gradient of a row-major
/// d×d projection (accumulating, fixed ascending-`p` order).
fn accum_wgrad(x: &[f32], g: &[f32], n: usize, d: usize, dw: &mut [f32]) {
    for p in 0..n {
        for kx in 0..d {
            let xv = x[p * d + kx];
            for j in 0..d {
                dw[kx * d + j] += xv * g[p * d + j];
            }
        }
    }
}

/// Forward one sample through the HSTU block stack. `x0` holds the
/// sample's `len` valid embedding rows (len×d). Returns the activation
/// tape: `xs[b]` is block `b`'s input, `xs[blocks]` the final hidden
/// state — everything else is recomputed by the backward.
fn hstu_sample_forward(
    params: &[f32],
    x0: Vec<f32>,
    len: usize,
    d: usize,
    heads: usize,
    blocks: usize,
    t: usize,
) -> Vec<Vec<f32>> {
    let dh = d / heads;
    let scale = 1.0 / (dh as f32).sqrt();
    let inv_n = 1.0 / len.max(1) as f32;
    let mut xs: Vec<Vec<f32>> = Vec::with_capacity(blocks + 1);
    xs.push(x0);
    let mut q = vec![0.0f32; len * d];
    let mut k = vec![0.0f32; len * d];
    let mut v = vec![0.0f32; len * d];
    let mut u = vec![0.0f32; len * d];
    for b in 0..blocks {
        let (wq, wk, wv, wu, wo) = hstu_block_weights(params, t, d, b);
        let x = xs.last().unwrap().clone();
        matmul_nd(&x, wq, len, d, &mut q);
        matmul_nd(&x, wk, len, d, &mut k);
        matmul_nd(&x, wv, len, d, &mut v);
        matmul_nd(&x, wu, len, d, &mut u);
        // SiLU-gated causal attention per head (the pointwise kernel:
        // no softmax, mask + 1/len folded into the weights). Only
        // kk ≤ p positions contribute, and every row is valid (x holds
        // exactly the `len` real rows).
        let mut m = vec![0.0f32; len * d];
        for h in 0..heads {
            let hc = h * dh;
            for p in 0..len {
                for kk in 0..=p {
                    let mut s = 0.0f32;
                    for jj in 0..dh {
                        s += q[p * d + hc + jj] * k[kk * d + hc + jj];
                    }
                    let w = silu(s * scale) * inv_n;
                    for jj in 0..dh {
                        m[p * d + hc + jj] += w * v[kk * d + hc + jj];
                    }
                }
            }
        }
        // U gate, output projection, residual: y = x + (M ⊙ U)·Wo.
        let mut a = m;
        for (av, uv) in a.iter_mut().zip(u.iter()) {
            *av *= *uv;
        }
        let mut y = x;
        for p in 0..len {
            for jj in 0..d {
                let mut acc = 0.0f32;
                for kx in 0..d {
                    acc += a[p * d + kx] * wo[kx * d + jj];
                }
                y[p * d + jj] += acc;
            }
        }
        xs.push(y);
    }
    xs
}

/// Backward through the HSTU stack. `gy` enters as dL/d(final hidden
/// state) and leaves as dL/d(embedding rows); parameter gradients
/// accumulate into `grads` (full-length dense gradient vector). Each
/// block's Q/K/V/U/scores are recomputed from its stored input.
fn hstu_sample_backward(
    params: &[f32],
    xs: &[Vec<f32>],
    gy: &mut [f32],
    grads: &mut [f32],
    len: usize,
    d: usize,
    heads: usize,
    blocks: usize,
    t: usize,
) {
    let dh = d / heads;
    let scale = 1.0 / (dh as f32).sqrt();
    let inv_n = 1.0 / len.max(1) as f32;
    let dd = d * d;
    let mut q = vec![0.0f32; len * d];
    let mut k = vec![0.0f32; len * d];
    let mut v = vec![0.0f32; len * d];
    let mut u = vec![0.0f32; len * d];
    for b in (0..blocks).rev() {
        let off = hstu_block_off(t, d, b);
        let (wq, wk, wv, wu, wo) = hstu_block_weights(params, t, d, b);
        let x = &xs[b];
        matmul_nd(x, wq, len, d, &mut q);
        matmul_nd(x, wk, len, d, &mut k);
        matmul_nd(x, wv, len, d, &mut v);
        matmul_nd(x, wu, len, d, &mut u);
        // Recompute M, keeping the pre-activation scores for dSiLU.
        let mut m = vec![0.0f32; len * d];
        let mut s_all = vec![0.0f32; heads * len * len];
        for h in 0..heads {
            let hc = h * dh;
            let s_mat = &mut s_all[h * len * len..(h + 1) * len * len];
            for p in 0..len {
                for kk in 0..=p {
                    let mut s = 0.0f32;
                    for jj in 0..dh {
                        s += q[p * d + hc + jj] * k[kk * d + hc + jj];
                    }
                    let sv = s * scale;
                    s_mat[p * len + kk] = sv;
                    let w = silu(sv) * inv_n;
                    for jj in 0..dh {
                        m[p * d + hc + jj] += w * v[kk * d + hc + jj];
                    }
                }
            }
        }
        // Output projection: dWo += Aᵀ·gy, gA = gy·Woᵀ (reads of the
        // incoming gy all happen before it is overwritten below).
        let mut a = vec![0.0f32; len * d];
        for idx in 0..len * d {
            a[idx] = m[idx] * u[idx];
        }
        accum_wgrad(&a, gy, len, d, &mut grads[off + 4 * dd..off + 5 * dd]);
        let mut ga = vec![0.0f32; len * d];
        matmul_nd_wt(gy, wo, len, d, &mut ga);
        // U gate backward: gU = gA ⊙ M, gM = gA ⊙ U.
        let mut gu = vec![0.0f32; len * d];
        let mut gm = vec![0.0f32; len * d];
        for idx in 0..len * d {
            gu[idx] = ga[idx] * m[idx];
            gm[idx] = ga[idx] * u[idx];
        }
        // Attention backward per head, gP/gS fused per (p, kk) pair so
        // no len×len gradient is materialized:
        //   gP[p,kk] = gM_h[p]·V_h[kk]      gV_h[kk] += P[p,kk]·gM_h[p]
        //   gS = gP·(1/len)·SiLU'(S)·(1/√dh)
        //   gQ_h[p] += gS·K_h[kk]           gK_h[kk] += gS·Q_h[p]
        let mut gq = vec![0.0f32; len * d];
        let mut gk = vec![0.0f32; len * d];
        let mut gv = vec![0.0f32; len * d];
        for h in 0..heads {
            let hc = h * dh;
            let s_mat = &s_all[h * len * len..(h + 1) * len * len];
            for p in 0..len {
                for kk in 0..=p {
                    let sv = s_mat[p * len + kk];
                    let w = silu(sv) * inv_n;
                    let mut gp = 0.0f32;
                    for jj in 0..dh {
                        let g = gm[p * d + hc + jj];
                        gp += g * v[kk * d + hc + jj];
                        gv[kk * d + hc + jj] += w * g;
                    }
                    let gs = gp * inv_n * dsilu(sv) * scale;
                    for jj in 0..dh {
                        gq[p * d + hc + jj] += gs * k[kk * d + hc + jj];
                        gk[kk * d + hc + jj] += gs * q[p * d + hc + jj];
                    }
                }
            }
        }
        // Projection weight grads + input grad (residual term is the
        // incoming gy itself, so the four products accumulate onto it).
        accum_wgrad(x, &gq, len, d, &mut grads[off..off + dd]);
        accum_wgrad(x, &gk, len, d, &mut grads[off + dd..off + 2 * dd]);
        accum_wgrad(x, &gv, len, d, &mut grads[off + 2 * dd..off + 3 * dd]);
        accum_wgrad(x, &gu, len, d, &mut grads[off + 3 * dd..off + 4 * dd]);
        matmul_nd_wt(&gq, wq, len, d, gy);
        matmul_nd_wt(&gk, wk, len, d, gy);
        matmul_nd_wt(&gv, wv, len, d, gy);
        matmul_nd_wt(&gu, wu, len, d, gy);
    }
}

/// Masked mean-pool over the final hidden state + the task heads —
/// shared verbatim by the HSTU train and forward paths so their logits
/// are bit-identical. With `len == 0`, `xfin` is never read and the
/// logits are the head biases (pooled = 0).
fn pooled_logits(
    params: &[f32],
    xfin: &[f32],
    len: usize,
    d: usize,
    t: usize,
    pooled: &mut [f32],
    logits: &mut [f32],
) {
    pooled.fill(0.0);
    if len > 0 {
        for pos in 0..len {
            for jj in 0..d {
                pooled[jj] += xfin[pos * d + jj];
            }
        }
        let inv = 1.0 / len as f32;
        for a in pooled.iter_mut() {
            *a *= inv;
        }
    }
    for kt in 0..t {
        let off = kt * (d + 1);
        let w = &params[off..off + d];
        let mut z = params[off + d];
        for jj in 0..d {
            z += w[jj] * pooled[jj];
        }
        logits[kt] = z;
    }
}

/// Validate the HSTU shape contract (head divisibility + parameter
/// budget for the full block stack).
fn ensure_hstu_shape(arts: &ModelArtifacts, d: usize, t: usize, p: usize) -> Result<()> {
    ensure!(
        arts.heads >= 1 && d % arts.heads == 0,
        "HSTU needs emb_dim divisible by heads (d={d}, heads={})",
        arts.heads
    );
    let need = hstu_block_off(t, d, arts.blocks);
    ensure!(
        p >= need,
        "HSTU model needs {need} dense params ({} blocks at d={d}), manifest says {p}",
        arts.blocks
    );
    Ok(())
}

/// Reusable output + intermediate buffers for [`train_into`]: the
/// trainer keeps one per worker so the dense step allocates nothing in
/// steady state. Public fields are the train artifact's 5-tuple.
#[derive(Debug, Default)]
pub struct TrainScratch {
    /// Per-task loss sums over valid samples (length `T`).
    pub loss_sums: Vec<f32>,
    /// Flat dense gradient (length `P`).
    pub grads: Vec<f32>,
    /// Gradient w.r.t. the embedding input (`B·L·D`).
    pub emb_grad: Vec<f32>,
    /// Logits (`B·T`).
    pub logits: Vec<f32>,
    /// Number of valid (non-padded) samples.
    pub n_valid: f32,
    // ---- internals ---------------------------------------------------
    pool: Vec<f32>,
    dz: Vec<f32>,
    chunk_loss: Vec<f32>,
    chunk_grads: Vec<f32>,
    chunk_valid: Vec<f32>,
}

impl TrainScratch {
    pub fn new() -> Self {
        TrainScratch::default()
    }
}

/// One chunk's forward + backward over samples `r` (global indices).
/// Every slice argument is the chunk's disjoint window; `loss_c`,
/// `grads_c` and `valid_c` are this chunk's private partial reductions.
#[allow(clippy::too_many_arguments)]
fn train_chunk(
    params: &[f32],
    emb: &[f32],
    lengths: &[i32],
    labels: &[f32],
    r: Range<usize>,
    l: usize,
    d: usize,
    t: usize,
    pool_c: &mut [f32],
    logits_c: &mut [f32],
    dz_c: &mut [f32],
    eg_c: &mut [f32],
    loss_c: &mut [f32],
    grads_c: &mut [f32],
    valid_c: &mut f32,
) {
    let base = r.start;
    let mut gvec = vec![0.0f32; d];
    for i in r {
        let j = i - base;
        let len = lengths[i].clamp(0, l as i32) as usize;

        // ---- masked mean-pool ---------------------------------------
        if len > 0 {
            let acc = &mut pool_c[j * d..(j + 1) * d];
            for pos in 0..len {
                let row = &emb[(i * l + pos) * d..(i * l + pos + 1) * d];
                for (a, x) in acc.iter_mut().zip(row) {
                    *a += x;
                }
            }
            let inv = 1.0 / len as f32;
            for a in acc.iter_mut() {
                *a *= inv;
            }
        }

        // ---- linear heads -------------------------------------------
        for k in 0..t {
            let off = k * (d + 1);
            let w = &params[off..off + d];
            let mut z = params[off + d];
            for jj in 0..d {
                z += w[jj] * pool_c[j * d + jj];
            }
            logits_c[j * t + k] = z;
        }
        if len == 0 {
            continue; // padded sample: logits only, zero gradients
        }
        *valid_c += 1.0;

        // ---- loss + dz ----------------------------------------------
        for k in 0..t {
            let z = logits_c[j * t + k];
            let y = labels[i * t + k];
            loss_c[k] += softplus(z) - y * z;
            dz_c[j * t + k] = sigmoid(z) - y;
        }

        // ---- head parameter gradients (chunk partials) --------------
        for k in 0..t {
            let g = dz_c[j * t + k];
            let off = k * (d + 1);
            for jj in 0..d {
                grads_c[off + jj] += g * pool_c[j * d + jj];
            }
            grads_c[off + d] += g;
        }

        // ---- embedding gradient -------------------------------------
        // d loss / d emb[i, pos, :] = Σ_k dz[i,k] · w_k / len_i on valid
        // positions; exactly zero on padding (the contract the
        // trainer's scatter relies on).
        gvec.fill(0.0);
        let inv = 1.0 / len as f32;
        for k in 0..t {
            let w = &params[k * (d + 1)..k * (d + 1) + d];
            let g = dz_c[j * t + k] * inv;
            for jj in 0..d {
                gvec[jj] += g * w[jj];
            }
        }
        for pos in 0..len {
            eg_c[(j * l + pos) * d..(j * l + pos + 1) * d].copy_from_slice(&gvec);
        }
    }
}

/// One chunk's HSTU forward + backward over samples `r` — the same
/// disjoint-window contract as [`train_chunk`], with the block stack in
/// place of the bare mean-pool. Per-sample work is independent and runs
/// in a fixed arithmetic order, so chunked execution stays bit-identical
/// at every pool size.
#[allow(clippy::too_many_arguments)]
fn hstu_train_chunk(
    params: &[f32],
    emb: &[f32],
    lengths: &[i32],
    labels: &[f32],
    r: Range<usize>,
    l: usize,
    d: usize,
    t: usize,
    heads: usize,
    blocks: usize,
    pool_c: &mut [f32],
    logits_c: &mut [f32],
    dz_c: &mut [f32],
    eg_c: &mut [f32],
    loss_c: &mut [f32],
    grads_c: &mut [f32],
    valid_c: &mut f32,
) {
    let base = r.start;
    let mut gpool = vec![0.0f32; d];
    for i in r {
        let j = i - base;
        let len = lengths[i].clamp(0, l as i32) as usize;
        if len == 0 {
            // Padded sample: logits from the zero pooled state (head
            // biases), gradients exactly zero, not counted valid.
            pooled_logits(
                params,
                &[],
                0,
                d,
                t,
                &mut pool_c[j * d..(j + 1) * d],
                &mut logits_c[j * t..(j + 1) * t],
            );
            continue;
        }
        let mut x0 = vec![0.0f32; len * d];
        x0.copy_from_slice(&emb[(i * l) * d..(i * l + len) * d]);
        let xs = hstu_sample_forward(params, x0, len, d, heads, blocks, t);
        pooled_logits(
            params,
            xs.last().unwrap(),
            len,
            d,
            t,
            &mut pool_c[j * d..(j + 1) * d],
            &mut logits_c[j * t..(j + 1) * t],
        );
        *valid_c += 1.0;

        // ---- loss + dz + head parameter gradients -------------------
        for kt in 0..t {
            let z = logits_c[j * t + kt];
            let y = labels[i * t + kt];
            loss_c[kt] += softplus(z) - y * z;
            dz_c[j * t + kt] = sigmoid(z) - y;
        }
        for kt in 0..t {
            let g = dz_c[j * t + kt];
            let off = kt * (d + 1);
            for jj in 0..d {
                grads_c[off + jj] += g * pool_c[j * d + jj];
            }
            grads_c[off + d] += g;
        }

        // ---- backward: heads → pooled → rows → block stack ----------
        // d loss / d pooled, broadcast at 1/len to every valid row (the
        // mean-pool backward), then pushed through the blocks with
        // recomputation.
        let inv = 1.0 / len as f32;
        gpool.fill(0.0);
        for kt in 0..t {
            let w = &params[kt * (d + 1)..kt * (d + 1) + d];
            let g = dz_c[j * t + kt] * inv;
            for jj in 0..d {
                gpool[jj] += g * w[jj];
            }
        }
        let mut gy = vec![0.0f32; len * d];
        for pos in 0..len {
            gy[pos * d..(pos + 1) * d].copy_from_slice(&gpool);
        }
        hstu_sample_backward(params, &xs, &mut gy, grads_c, len, d, heads, blocks, t);
        for pos in 0..len {
            eg_c[(j * l + pos) * d..(j * l + pos + 1) * d]
                .copy_from_slice(&gy[pos * d..(pos + 1) * d]);
        }
    }
}

/// Execute one train step into `s`, chunking the batch across `pool`
/// (serial and bit-identical when `pool` is `None` or single-share).
#[allow(clippy::too_many_arguments)]
pub fn train_into(
    arts: &ModelArtifacts,
    bucket: (usize, usize),
    params: &[f32],
    emb: &[f32],
    lengths: &[i32],
    labels: &[f32],
    pool: Option<&WorkerPool>,
    s: &mut TrainScratch,
) -> Result<()> {
    let (b, l) = bucket;
    let d = arts.emb_dim;
    let t = arts.tasks;
    let p = arts.param_count;
    ensure!(
        p >= t * (d + 1),
        "reference model needs {} head params, manifest says {p}",
        t * (d + 1)
    );
    if arts.arch == ModelArch::Hstu {
        ensure_hstu_shape(arts, d, t, p)?;
    }
    ensure!(params.len() == p, "params arity: {} vs {p}", params.len());
    ensure!(emb.len() == b * l * d, "emb arity: {} vs {}", emb.len(), b * l * d);
    ensure!(lengths.len() == b, "lengths arity: {} vs {b}", lengths.len());
    ensure!(labels.len() == b * t, "labels arity: {} vs {}", labels.len(), b * t);

    let ranges = WorkerPool::chunk_ranges(b, DENSE_CHUNKS);
    let nc = ranges.len();

    // Zero-fill (capacity is retained across steps, so no allocation in
    // steady state; zeroing is required either way).
    s.loss_sums.clear();
    s.loss_sums.resize(t, 0.0);
    s.grads.clear();
    s.grads.resize(p, 0.0);
    s.emb_grad.clear();
    s.emb_grad.resize(b * l * d, 0.0);
    s.logits.clear();
    s.logits.resize(b * t, 0.0);
    s.n_valid = 0.0;
    s.pool.clear();
    s.pool.resize(b * d, 0.0);
    s.dz.clear();
    s.dz.resize(b * t, 0.0);
    s.chunk_loss.clear();
    s.chunk_loss.resize(nc * t, 0.0);
    s.chunk_grads.clear();
    s.chunk_grads.resize(nc * p, 0.0);
    s.chunk_valid.clear();
    s.chunk_valid.resize(nc, 0.0);

    if nc > 0 {
        let pool_w = SharedSliceMut::new(&mut s.pool);
        let logits_w = SharedSliceMut::new(&mut s.logits);
        let dz_w = SharedSliceMut::new(&mut s.dz);
        let eg_w = SharedSliceMut::new(&mut s.emb_grad);
        let loss_w = SharedSliceMut::new(&mut s.chunk_loss);
        let grads_w = SharedSliceMut::new(&mut s.chunk_grads);
        let valid_w = SharedSliceMut::new(&mut s.chunk_valid);
        let arch = arts.arch;
        let (heads, blocks) = (arts.heads, arts.blocks);
        let run_chunk = |ci: usize, r: Range<usize>| {
            let n = r.len();
            // SAFETY: `ranges` partitions `0..b` into disjoint chunks
            // and each (ci, r) pair is handed to exactly one task, so
            // every window below is written by exactly one chunk; the
            // windows live only inside this scope.
            unsafe {
                match arch {
                    ModelArch::MeanPool => train_chunk(
                        params,
                        emb,
                        lengths,
                        labels,
                        r.clone(),
                        l,
                        d,
                        t,
                        pool_w.slice_mut(r.start * d, n * d),
                        logits_w.slice_mut(r.start * t, n * t),
                        dz_w.slice_mut(r.start * t, n * t),
                        eg_w.slice_mut(r.start * l * d, n * l * d),
                        loss_w.slice_mut(ci * t, t),
                        grads_w.slice_mut(ci * p, p),
                        &mut valid_w.slice_mut(ci, 1)[0],
                    ),
                    ModelArch::Hstu => hstu_train_chunk(
                        params,
                        emb,
                        lengths,
                        labels,
                        r.clone(),
                        l,
                        d,
                        t,
                        heads,
                        blocks,
                        pool_w.slice_mut(r.start * d, n * d),
                        logits_w.slice_mut(r.start * t, n * t),
                        dz_w.slice_mut(r.start * t, n * t),
                        eg_w.slice_mut(r.start * l * d, n * l * d),
                        loss_w.slice_mut(ci * t, t),
                        grads_w.slice_mut(ci * p, p),
                        &mut valid_w.slice_mut(ci, 1)[0],
                    ),
                }
            }
        };
        match pool {
            Some(pl) if pl.threads() > 1 && nc > 1 => {
                let run_chunk = &run_chunk;
                let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = ranges
                    .iter()
                    .enumerate()
                    .map(|(ci, r)| {
                        let r = r.clone();
                        Box::new(move || run_chunk(ci, r)) as Box<dyn FnOnce() + Send + '_>
                    })
                    .collect();
                pl.run_scope(tasks);
            }
            _ => {
                for (ci, r) in ranges.iter().enumerate() {
                    run_chunk(ci, r.clone());
                }
            }
        }
    }

    // Fold the per-chunk partial reductions in fixed ascending chunk
    // order — the association is identical for every pool size.
    for ci in 0..nc {
        for k in 0..t {
            s.loss_sums[k] += s.chunk_loss[ci * t + k];
        }
        for j in 0..p {
            s.grads[j] += s.chunk_grads[ci * p + j];
        }
        s.n_valid += s.chunk_valid[ci];
    }
    Ok(())
}

/// Execute one request against the reference model (serial).
pub fn execute(
    arts: &ModelArtifacts,
    kind: ArtifactKind,
    bucket: (usize, usize),
    inputs: &[Tensor],
) -> Result<Vec<Tensor>> {
    execute_with_pool(arts, kind, bucket, inputs, None)
}

/// [`execute`] with an optional worker pool for the train path's
/// batch-chunked forward/backward.
pub fn execute_with_pool(
    arts: &ModelArtifacts,
    kind: ArtifactKind,
    bucket: (usize, usize),
    inputs: &[Tensor],
    pool: Option<&WorkerPool>,
) -> Result<Vec<Tensor>> {
    let (b, l) = bucket;
    let d = arts.emb_dim;
    let t = arts.tasks;
    let want = match kind {
        ArtifactKind::Train => 4,
        ArtifactKind::Forward => 3,
    };
    ensure!(inputs.len() == want, "expected {want} inputs, got {}", inputs.len());

    let params = inputs[0].as_f32()?;
    let emb = inputs[1].as_f32()?;
    let lengths = match &inputs[2] {
        Tensor::I32 { data, .. } => data.as_slice(),
        _ => bail!("lengths tensor is not i32"),
    };

    if kind == ArtifactKind::Forward {
        let p = arts.param_count;
        ensure!(
            p >= t * (d + 1),
            "reference model needs {} head params, manifest says {p}",
            t * (d + 1)
        );
        ensure!(params.len() == p, "params arity: {} vs {p}", params.len());
        ensure!(emb.len() == b * l * d, "emb arity: {} vs {}", emb.len(), b * l * d);
        ensure!(lengths.len() == b, "lengths arity: {} vs {b}", lengths.len());
        if arts.arch == ModelArch::Hstu {
            // Same per-sample arithmetic as hstu_train_chunk (shared
            // helpers), so forward logits are bit-identical to train.
            ensure_hstu_shape(arts, d, t, p)?;
            let mut logits = vec![0.0f32; b * t];
            let mut pooled = vec![0.0f32; d];
            for i in 0..b {
                let len = lengths[i].clamp(0, l as i32) as usize;
                if len == 0 {
                    pooled_logits(
                        params,
                        &[],
                        0,
                        d,
                        t,
                        &mut pooled,
                        &mut logits[i * t..(i + 1) * t],
                    );
                    continue;
                }
                let mut x0 = vec![0.0f32; len * d];
                x0.copy_from_slice(&emb[(i * l) * d..(i * l + len) * d]);
                let xs =
                    hstu_sample_forward(params, x0, len, d, arts.heads, arts.blocks, t);
                pooled_logits(
                    params,
                    xs.last().unwrap(),
                    len,
                    d,
                    t,
                    &mut pooled,
                    &mut logits[i * t..(i + 1) * t],
                );
            }
            return Ok(vec![Tensor::f32(&[b, t], logits)]);
        }
        // Per-sample arithmetic is identical to the train path (which
        // the `forward_matches_train_logits` test pins down).
        let mut logits = vec![0.0f32; b * t];
        let mut acc = vec![0.0f32; d];
        for i in 0..b {
            let len = lengths[i].clamp(0, l as i32) as usize;
            acc.fill(0.0);
            if len > 0 {
                for pos in 0..len {
                    let row = &emb[(i * l + pos) * d..(i * l + pos + 1) * d];
                    for (a, x) in acc.iter_mut().zip(row) {
                        *a += x;
                    }
                }
                let inv = 1.0 / len as f32;
                for a in acc.iter_mut() {
                    *a *= inv;
                }
            }
            for k in 0..t {
                let off = k * (d + 1);
                let w = &params[off..off + d];
                let mut z = params[off + d];
                for jj in 0..d {
                    z += w[jj] * acc[jj];
                }
                logits[i * t + k] = z;
            }
        }
        return Ok(vec![Tensor::f32(&[b, t], logits)]);
    }

    let labels = inputs[3].as_f32()?;
    let mut s = TrainScratch::new();
    train_into(arts, bucket, params, emb, lengths, labels, pool, &mut s)?;
    Ok(vec![
        Tensor::f32(&[t], std::mem::take(&mut s.loss_sums)),
        Tensor::f32(&[arts.param_count], std::mem::take(&mut s.grads)),
        Tensor::f32(&[b, l, d], std::mem::take(&mut s.emb_grad)),
        Tensor::f32(&[b, t], std::mem::take(&mut s.logits)),
        Tensor::scalar_f32(s.n_valid),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Bucket;
    use crate::util::rng::Xoshiro256;

    const B: usize = 3;
    const L: usize = 4;
    const D: usize = 2;
    const T: usize = 2;
    const P: usize = 10; // ≥ T·(D+1) = 6

    fn arts() -> ModelArtifacts {
        ModelArtifacts {
            name: "ref-test".into(),
            emb_dim: D,
            heads: 1,
            blocks: 1,
            tasks: T,
            param_count: P,
            params_bin: "<builtin>".into(),
            params_seed: 0,
            arch: ModelArch::MeanPool,
            buckets: vec![Bucket {
                batch: B,
                len: L,
                train: "<builtin>".into(),
                forward: "<builtin>".into(),
            }],
        }
    }

    // HSTU fixture: d=4, 2 heads, 2 blocks → exactly
    // hstu_block_off(T, 4, 2) = 2·5 + 2·(5·16 + 9·4) = 242 params.
    const HD: usize = 4;
    const HP: usize = 242;

    fn hstu_arts() -> ModelArtifacts {
        ModelArtifacts {
            name: "ref-hstu-test".into(),
            emb_dim: HD,
            heads: 2,
            blocks: 2,
            tasks: T,
            param_count: HP,
            params_bin: "<builtin>".into(),
            params_seed: 0,
            arch: ModelArch::Hstu,
            buckets: vec![Bucket {
                batch: B,
                len: L,
                train: "<builtin>".into(),
                forward: "<builtin>".into(),
            }],
        }
    }

    fn hstu_inputs(seed: u64) -> Vec<Tensor> {
        let mut rng = Xoshiro256::new(seed);
        let params: Vec<f32> = (0..HP).map(|_| rng.normal(0.0, 0.4) as f32).collect();
        let emb: Vec<f32> =
            (0..B * L * HD).map(|_| rng.normal(0.0, 1.0) as f32).collect();
        let lengths = vec![3, 1, 0]; // last sample padded out
        let labels: Vec<f32> = (0..B * T).map(|_| rng.gen_range(2) as f32).collect();
        vec![
            Tensor::f32(&[HP], params),
            Tensor::f32(&[B, L, HD], emb),
            Tensor::i32(&[B], lengths),
            Tensor::f32(&[B, T], labels),
        ]
    }

    fn inputs(seed: u64) -> Vec<Tensor> {
        let mut rng = Xoshiro256::new(seed);
        let params: Vec<f32> = (0..P).map(|_| rng.normal(0.0, 0.5) as f32).collect();
        let emb: Vec<f32> = (0..B * L * D).map(|_| rng.normal(0.0, 1.0) as f32).collect();
        let lengths = vec![3, 1, 0]; // last sample padded out
        let labels: Vec<f32> = (0..B * T).map(|_| rng.gen_range(2) as f32).collect();
        vec![
            Tensor::f32(&[P], params),
            Tensor::f32(&[B, L, D], emb),
            Tensor::i32(&[B], lengths),
            Tensor::f32(&[B, T], labels),
        ]
    }

    fn total_loss(out: &[Tensor]) -> f64 {
        out[0].as_f32().unwrap().iter().map(|&x| x as f64).sum()
    }

    #[test]
    fn shapes_and_padding_contract() {
        let a = arts();
        let out = execute(&a, ArtifactKind::Train, (B, L), &inputs(1)).unwrap();
        assert_eq!(out.len(), 5);
        assert_eq!(out[0].as_f32().unwrap().len(), T);
        assert_eq!(out[1].as_f32().unwrap().len(), P);
        assert_eq!(out[2].as_f32().unwrap().len(), B * L * D);
        assert_eq!(out[3].as_f32().unwrap().len(), B * T);
        assert_eq!(out[4].as_f32().unwrap()[0], 2.0, "one padded sample");
        // Padded sample's embedding gradient is exactly zero.
        let eg = out[2].as_f32().unwrap();
        assert!(eg[(B - 1) * L * D..].iter().all(|&x| x == 0.0));
        // And so are positions past each sequence's length (len 1 → pos ≥ 1).
        assert!(eg[(1 * L + 1) * D..2 * L * D].iter().all(|&x| x == 0.0));
        // Losses positive (BCE) and finite.
        assert!(out[0].as_f32().unwrap().iter().all(|&x| x > 0.0 && x.is_finite()));
    }

    #[test]
    fn forward_matches_train_logits() {
        let a = arts();
        let ins = inputs(2);
        let train = execute(&a, ArtifactKind::Train, (B, L), &ins).unwrap();
        let fwd = execute(&a, ArtifactKind::Forward, (B, L), &ins[..3]).unwrap();
        assert_eq!(fwd[0].as_f32().unwrap(), train[3].as_f32().unwrap());
    }

    #[test]
    fn bit_identical_across_runs() {
        let a = arts();
        let ins = inputs(3);
        let o1 = execute(&a, ArtifactKind::Train, (B, L), &ins).unwrap();
        let o2 = execute(&a, ArtifactKind::Train, (B, L), &ins).unwrap();
        for (x, y) in o1.iter().zip(&o2) {
            assert_eq!(x, y);
        }
    }

    #[test]
    fn pooled_execution_bit_identical_for_every_pool_size() {
        // A batch wide enough that every DENSE_CHUNKS chunk is
        // non-empty and threads ≠ chunks, exercising the queue.
        let mut a = arts();
        let (b, l) = (13usize, 6usize);
        a.buckets = vec![Bucket {
            batch: b,
            len: l,
            train: "<builtin>".into(),
            forward: "<builtin>".into(),
        }];
        let mut rng = Xoshiro256::new(17);
        let params: Vec<f32> = (0..P).map(|_| rng.normal(0.0, 0.5) as f32).collect();
        let emb: Vec<f32> = (0..b * l * D).map(|_| rng.normal(0.0, 1.0) as f32).collect();
        let lengths: Vec<i32> = (0..b).map(|i| (i % (l + 1)) as i32).collect();
        let labels: Vec<f32> = (0..b * T).map(|_| rng.gen_range(2) as f32).collect();
        let ins = vec![
            Tensor::f32(&[P], params),
            Tensor::f32(&[b, l, D], emb),
            Tensor::i32(&[b], lengths),
            Tensor::f32(&[b, T], labels),
        ];
        let serial = execute(&a, ArtifactKind::Train, (b, l), &ins).unwrap();
        for threads in [1usize, 2, 3, 4] {
            let pool = WorkerPool::new(threads);
            let par =
                execute_with_pool(&a, ArtifactKind::Train, (b, l), &ins, Some(&pool)).unwrap();
            for (x, y) in serial.iter().zip(&par) {
                assert_eq!(x, y, "{threads} threads diverged");
            }
        }
    }

    #[test]
    fn scratch_reuse_matches_fresh() {
        let a = arts();
        let ins = inputs(9);
        let params = ins[0].as_f32().unwrap();
        let emb = ins[1].as_f32().unwrap();
        let lengths = match &ins[2] {
            Tensor::I32 { data, .. } => data.as_slice(),
            _ => unreachable!(),
        };
        let labels = ins[3].as_f32().unwrap();
        let mut s = TrainScratch::new();
        train_into(&a, (B, L), params, emb, lengths, labels, None, &mut s).unwrap();
        let first = (
            s.loss_sums.clone(),
            s.grads.clone(),
            s.emb_grad.clone(),
            s.logits.clone(),
            s.n_valid,
        );
        // Dirty the scratch with a different step, then re-run: stale
        // contents must not leak into the outputs.
        let other = inputs(10);
        train_into(
            &a,
            (B, L),
            other[0].as_f32().unwrap(),
            other[1].as_f32().unwrap(),
            match &other[2] {
                Tensor::I32 { data, .. } => data.as_slice(),
                _ => unreachable!(),
            },
            other[3].as_f32().unwrap(),
            None,
            &mut s,
        )
        .unwrap();
        train_into(&a, (B, L), params, emb, lengths, labels, None, &mut s).unwrap();
        assert_eq!(s.loss_sums, first.0);
        assert_eq!(s.grads, first.1);
        assert_eq!(s.emb_grad, first.2);
        assert_eq!(s.logits, first.3);
        assert_eq!(s.n_valid, first.4);
    }

    #[test]
    fn param_gradients_match_finite_differences() {
        let a = arts();
        let ins = inputs(4);
        let base = execute(&a, ArtifactKind::Train, (B, L), &ins).unwrap();
        let grads = base[1].as_f32().unwrap().to_vec();
        let l0 = total_loss(&base);
        let eps = 1e-3f32;
        for idx in 0..T * (D + 1) {
            let mut bumped = ins.clone();
            if let Tensor::F32 { data, .. } = &mut bumped[0] {
                data[idx] += eps;
            }
            let l1 = total_loss(&execute(&a, ArtifactKind::Train, (B, L), &bumped).unwrap());
            let fd = (l1 - l0) / eps as f64;
            assert!(
                (fd - grads[idx] as f64).abs() < 2e-2,
                "param {idx}: fd {fd:.4} vs analytic {:.4}",
                grads[idx]
            );
        }
        // Params beyond the head carry exactly zero gradient.
        assert!(grads[T * (D + 1)..].iter().all(|&g| g == 0.0));
    }

    #[test]
    fn emb_gradients_match_finite_differences() {
        let a = arts();
        let ins = inputs(5);
        let base = execute(&a, ArtifactKind::Train, (B, L), &ins).unwrap();
        let eg = base[2].as_f32().unwrap().to_vec();
        let l0 = total_loss(&base);
        let eps = 1e-3f32;
        // Probe a handful of valid positions.
        for &idx in &[0usize, 1, D, 2 * D + 1, (1 * L) * D] {
            let mut bumped = ins.clone();
            if let Tensor::F32 { data, .. } = &mut bumped[1] {
                data[idx] += eps;
            }
            let l1 = total_loss(&execute(&a, ArtifactKind::Train, (B, L), &bumped).unwrap());
            let fd = (l1 - l0) / eps as f64;
            assert!(
                (fd - eg[idx] as f64).abs() < 2e-2,
                "emb {idx}: fd {fd:.4} vs analytic {:.4}",
                eg[idx]
            );
        }
    }

    #[test]
    fn bad_arity_and_small_param_count_rejected() {
        let a = arts();
        assert!(execute(&a, ArtifactKind::Train, (B, L), &inputs(6)[..2]).is_err());
        let mut small = arts();
        small.param_count = 2; // < T·(D+1)
        assert!(execute(&small, ArtifactKind::Train, (B, L), &inputs(7)).is_err());
    }

    // ---- HSTU architecture ---------------------------------------------

    #[test]
    fn hstu_layout_constants() {
        assert_eq!(hstu_block_stride(HD), 5 * HD * HD + 9 * HD);
        assert_eq!(hstu_block_off(T, HD, 2), HP, "fixture spans exactly 2 blocks");
        // The config's dense budget covers the executor's layout for the
        // real preset (slack ≥ 0 per block).
        let cfg = crate::config::ModelConfig::tiny_hstu();
        assert!(
            cfg.dense_params()
                >= hstu_block_off(cfg.num_tasks, cfg.emb_dim, cfg.hstu_blocks)
        );
    }

    #[test]
    fn hstu_shapes_and_padding_contract() {
        let a = hstu_arts();
        let ins = hstu_inputs(11);
        let out = execute(&a, ArtifactKind::Train, (B, L), &ins).unwrap();
        assert_eq!(out.len(), 5);
        assert_eq!(out[0].as_f32().unwrap().len(), T);
        assert_eq!(out[1].as_f32().unwrap().len(), HP);
        assert_eq!(out[2].as_f32().unwrap().len(), B * L * HD);
        assert_eq!(out[3].as_f32().unwrap().len(), B * T);
        assert_eq!(out[4].as_f32().unwrap()[0], 2.0, "one padded sample");
        // Padded sample: logits are the head biases, zero emb grad.
        let params = ins[0].as_f32().unwrap();
        let logits = out[3].as_f32().unwrap();
        for kt in 0..T {
            assert_eq!(logits[(B - 1) * T + kt], params[kt * (HD + 1) + HD]);
        }
        let eg = out[2].as_f32().unwrap();
        assert!(eg[(B - 1) * L * HD..].iter().all(|&x| x == 0.0));
        // Positions past each length carry exactly zero gradient too.
        assert!(eg[(1 * L + 1) * HD..2 * L * HD].iter().all(|&x| x == 0.0));
        assert!(out[0].as_f32().unwrap().iter().all(|&x| x > 0.0 && x.is_finite()));
    }

    #[test]
    fn hstu_forward_matches_train_logits() {
        let a = hstu_arts();
        let ins = hstu_inputs(12);
        let train = execute(&a, ArtifactKind::Train, (B, L), &ins).unwrap();
        let fwd = execute(&a, ArtifactKind::Forward, (B, L), &ins[..3]).unwrap();
        assert_eq!(fwd[0].as_f32().unwrap(), train[3].as_f32().unwrap());
    }

    #[test]
    fn hstu_param_gradients_match_finite_differences() {
        let a = hstu_arts();
        let ins = hstu_inputs(13);
        let base = execute(&a, ArtifactKind::Train, (B, L), &ins).unwrap();
        let grads = base[1].as_f32().unwrap().to_vec();
        let eps = 1e-3f32;
        // Central differences over EVERY parameter: the task heads and
        // all five projections of both blocks.
        for idx in 0..HP {
            let mut up = ins.clone();
            if let Tensor::F32 { data, .. } = &mut up[0] {
                data[idx] += eps;
            }
            let mut dn = ins.clone();
            if let Tensor::F32 { data, .. } = &mut dn[0] {
                data[idx] -= eps;
            }
            let l1 = total_loss(&execute(&a, ArtifactKind::Train, (B, L), &up).unwrap());
            let l2 = total_loss(&execute(&a, ArtifactKind::Train, (B, L), &dn).unwrap());
            let fd = (l1 - l2) / (2.0 * eps as f64);
            let g = grads[idx] as f64;
            assert!(
                (fd - g).abs() < 1e-2 + 1e-2 * g.abs(),
                "param {idx}: fd {fd:.5} vs analytic {g:.5}"
            );
        }
        // The 9d reserved tail of each block carries exactly zero grad.
        let dd = HD * HD;
        for blk in 0..2 {
            let off = hstu_block_off(T, HD, blk);
            assert!(
                grads[off + 5 * dd..off + hstu_block_stride(HD)]
                    .iter()
                    .all(|&g| g == 0.0),
                "block {blk} reserved tail must not train"
            );
        }
    }

    #[test]
    fn hstu_emb_gradients_match_finite_differences() {
        let a = hstu_arts();
        let ins = hstu_inputs(14);
        let base = execute(&a, ArtifactKind::Train, (B, L), &ins).unwrap();
        let eg = base[2].as_f32().unwrap().to_vec();
        let eps = 1e-3f32;
        // Every valid position of both live samples (lens 3 and 1).
        let mut probes: Vec<usize> = (0..3 * HD).collect();
        probes.extend((1 * L * HD)..(1 * L * HD + HD));
        for idx in probes {
            let mut up = ins.clone();
            if let Tensor::F32 { data, .. } = &mut up[1] {
                data[idx] += eps;
            }
            let mut dn = ins.clone();
            if let Tensor::F32 { data, .. } = &mut dn[1] {
                data[idx] -= eps;
            }
            let l1 = total_loss(&execute(&a, ArtifactKind::Train, (B, L), &up).unwrap());
            let l2 = total_loss(&execute(&a, ArtifactKind::Train, (B, L), &dn).unwrap());
            let fd = (l1 - l2) / (2.0 * eps as f64);
            let g = eg[idx] as f64;
            assert!(
                (fd - g).abs() < 1e-2 + 1e-2 * g.abs(),
                "emb {idx}: fd {fd:.5} vs analytic {g:.5}"
            );
        }
    }

    #[test]
    fn hstu_pooled_execution_bit_identical_for_every_pool_size() {
        let mut a = hstu_arts();
        let (b, l) = (13usize, 6usize);
        a.buckets = vec![Bucket {
            batch: b,
            len: l,
            train: "<builtin>".into(),
            forward: "<builtin>".into(),
        }];
        let mut rng = Xoshiro256::new(23);
        let params: Vec<f32> = (0..HP).map(|_| rng.normal(0.0, 0.4) as f32).collect();
        let emb: Vec<f32> =
            (0..b * l * HD).map(|_| rng.normal(0.0, 1.0) as f32).collect();
        let lengths: Vec<i32> = (0..b).map(|i| (i % (l + 1)) as i32).collect();
        let labels: Vec<f32> = (0..b * T).map(|_| rng.gen_range(2) as f32).collect();
        let ins = vec![
            Tensor::f32(&[HP], params),
            Tensor::f32(&[b, l, HD], emb),
            Tensor::i32(&[b], lengths),
            Tensor::f32(&[b, T], labels),
        ];
        let serial = execute(&a, ArtifactKind::Train, (b, l), &ins).unwrap();
        for threads in [1usize, 2, 3, 4] {
            let pool = WorkerPool::new(threads);
            let par =
                execute_with_pool(&a, ArtifactKind::Train, (b, l), &ins, Some(&pool)).unwrap();
            for (x, y) in serial.iter().zip(&par) {
                assert_eq!(x, y, "{threads} threads diverged");
            }
        }
    }

    #[test]
    fn hstu_bad_shapes_rejected() {
        // emb_dim not divisible by heads.
        let mut odd = hstu_arts();
        odd.heads = 3;
        assert!(execute(&odd, ArtifactKind::Train, (B, L), &hstu_inputs(15)).is_err());
        assert!(execute(&odd, ArtifactKind::Forward, (B, L), &hstu_inputs(15)[..3]).is_err());
        // Parameter budget below the block stack's need.
        let mut small = hstu_arts();
        small.param_count = HP - 1;
        let mut ins = hstu_inputs(16);
        if let Tensor::F32 { data, shape } = &mut ins[0] {
            data.pop();
            shape[0] -= 1;
        }
        assert!(execute(&small, ArtifactKind::Train, (B, L), &ins).is_err());
    }
}
