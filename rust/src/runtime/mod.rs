//! Execution runtime: load the AOT HLO-text artifacts (or the built-in
//! reference manifest) and execute train/forward steps from the Rust
//! hot path.
//!
//! - [`manifest`] — parse `artifacts/manifest.json` (bucket list, param
//!   counts, artifact file names), load `*_params.bin`, or synthesize
//!   the in-memory reference manifest ([`Manifest::reference`]).
//! - [`engine`] — the execution service. PJRT handles are not `Send`, so
//!   a dedicated engine thread owns the `PjRtClient` and the compiled
//!   executables (lazily compiled per (model, bucket, kind)); worker
//!   threads submit [`engine::Tensor`] batches over a channel and block
//!   on the reply. This mirrors a real deployment where device streams
//!   are owned by a driver thread. Without the `pjrt` feature the same
//!   channel is served by the reference backend.
//! - [`reference`] — deterministic pure-Rust train/forward executor
//!   honoring the exact artifact contract, so the full distributed
//!   trainer runs offline and bit-reproducibly. Two dense architectures
//!   ([`ModelArch`]): masked mean-pool + per-task linear heads + BCE
//!   (the historical toy), and HSTU-style pointwise-gated attention
//!   blocks (`tiny-hstu`) with an exact recomputed backward. The train
//!   path chunks the batch over the shared worker pool (fixed chunk
//!   count, chunk-ordered partial-reduction fold) so the dense
//!   forward/backward scales with threads while staying bit-identical
//!   at every pool size; reference-backend engines execute it *inline*
//!   on the calling worker (no channel serialization) into a reusable
//!   [`reference::TrainScratch`] arena.

pub mod engine;
pub mod manifest;
pub mod reference;

pub use engine::{Engine, Tensor, TrainOutputs};
pub use manifest::{ArtifactKind, Bucket, Manifest, ModelArch, ModelArtifacts};
pub use reference::TrainScratch;
