//! PJRT runtime: load the AOT HLO-text artifacts and execute them from
//! the Rust hot path.
//!
//! - [`manifest`] — parse `artifacts/manifest.json` (bucket list, param
//!   counts, artifact file names) and load `*_params.bin`.
//! - [`engine`] — the execution service. PJRT handles are not `Send`, so
//!   a dedicated engine thread owns the `PjRtClient` and the compiled
//!   executables (lazily compiled per (model, bucket, kind)); worker
//!   threads submit [`engine::Tensor`] batches over a channel and block
//!   on the reply. This mirrors a real deployment where device streams
//!   are owned by a driver thread.

pub mod engine;
pub mod manifest;

pub use engine::{Engine, Tensor, TrainOutputs};
pub use manifest::{ArtifactKind, Bucket, Manifest, ModelArtifacts};
