//! Parse `artifacts/manifest.json` and load parameter binaries.
//!
//! The manifest is produced by `python/compile/aot.py` and is the single
//! source of truth the Rust side has about the L2 model: parameter
//! count, embedding dim, task count, and the (batch, length) buckets
//! with their HLO-text artifact file names.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Marker used in place of an artifact/params file name by the built-in
/// reference manifest ([`Manifest::reference`]): the reference backend
/// synthesizes these deterministically instead of reading disk.
pub const BUILTIN: &str = "<builtin>";

/// Which artifact of a bucket to execute.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ArtifactKind {
    /// `train_step`: (params, emb, lengths, labels) →
    /// (loss_sums, grads, emb_grad, logits, n_valid).
    Train,
    /// inference `forward`: (params, emb, lengths) → (logits,).
    Forward,
}

/// One compiled (batch, length) bucket.
#[derive(Clone, Debug, PartialEq)]
pub struct Bucket {
    pub batch: usize,
    pub len: usize,
    pub train: String,
    pub forward: String,
}

impl Bucket {
    pub fn artifact(&self, kind: ArtifactKind) -> &str {
        match kind {
            ArtifactKind::Train => &self.train,
            ArtifactKind::Forward => &self.forward,
        }
    }

    /// Padded token capacity of the bucket.
    pub fn capacity(&self) -> usize {
        self.batch * self.len
    }
}

/// Dense architecture the reference backend executes for a model. PJRT
/// artifacts carry their architecture inside the compiled HLO, so this
/// only steers the built-in reference executor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelArch {
    /// Masked mean-pool + per-task linear heads (the historical toy).
    MeanPool,
    /// HSTU-style pointwise-gated attention blocks (SiLU-gated causal
    /// attention over variable-length sequences) feeding the same
    /// heads — paper-shaped dense FLOPs.
    Hstu,
}

/// Everything the runtime knows about one model.
#[derive(Clone, Debug)]
pub struct ModelArtifacts {
    pub name: String,
    pub emb_dim: usize,
    pub heads: usize,
    pub blocks: usize,
    pub tasks: usize,
    pub param_count: usize,
    pub params_bin: String,
    /// Seed mixed into built-in parameter generation (the manifest
    /// seed); ignored when `params_bin` names a real file.
    pub params_seed: u64,
    /// Dense architecture for the reference executor.
    pub arch: ModelArch,
    /// Sorted ascending by (batch, len).
    pub buckets: Vec<Bucket>,
}

impl ModelArtifacts {
    /// Smallest bucket that fits `batch` sequences with max length
    /// `max_len`. Returns `None` when nothing fits (caller splits the
    /// batch or uses the largest bucket with truncated batch count).
    pub fn pick_bucket(&self, batch: usize, max_len: usize) -> Option<&Bucket> {
        self.buckets
            .iter()
            .find(|b| b.batch >= batch && b.len >= max_len)
    }

    /// The largest bucket (fallback / e2e default).
    pub fn largest_bucket(&self) -> &Bucket {
        self.buckets.last().expect("no buckets")
    }

    /// Load the initial dense parameter vector. Built-in models generate
    /// theirs deterministically (a pure function of model name and param
    /// count, so every worker and every process agrees bit-for-bit).
    pub fn load_params(&self, dir: &Path) -> Result<Vec<f32>> {
        if self.params_bin == BUILTIN {
            let name_hash = crate::embedding::hash::murmur3_x86_32(self.name.as_bytes(), 7);
            let seed = crate::embedding::hash::hash_id(
                self.param_count as u64 ^ self.params_seed,
                name_hash as u64,
            );
            let mut rng = crate::util::rng::Xoshiro256::new(seed);
            return Ok((0..self.param_count)
                .map(|_| rng.normal(0.0, 0.05) as f32)
                .collect());
        }
        let path = dir.join(&self.params_bin);
        let bytes = std::fs::read(&path)
            .with_context(|| format!("read {}", path.display()))?;
        if bytes.len() != self.param_count * 4 {
            bail!(
                "{}: expected {} f32 ({} bytes), got {} bytes",
                path.display(),
                self.param_count,
                self.param_count * 4,
                bytes.len()
            );
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub seed: u64,
    pub models: BTreeMap<String, ModelArtifacts>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {} (run `make artifacts`)", path.display()))?;
        let v = Json::parse(&text).context("parse manifest.json")?;
        let seed = v.get("seed").as_usize().unwrap_or(0) as u64;
        let mut models = BTreeMap::new();
        let model_obj = v
            .get("models")
            .as_obj()
            .context("manifest: `models` object missing")?;
        for (name, m) in model_obj {
            let mut buckets = Vec::new();
            for b in m.expect_arr("buckets")? {
                buckets.push(Bucket {
                    batch: b.expect_usize("batch")?,
                    len: b.expect_usize("len")?,
                    train: b.expect_str("train")?.to_string(),
                    forward: b.expect_str("forward")?.to_string(),
                });
            }
            buckets.sort_by_key(|b| (b.batch, b.len));
            models.insert(
                name.clone(),
                ModelArtifacts {
                    name: name.clone(),
                    emb_dim: m.expect_usize("emb_dim")?,
                    heads: m.expect_usize("heads")?,
                    blocks: m.expect_usize("blocks")?,
                    tasks: m.expect_usize("tasks")?,
                    param_count: m.expect_usize("param_count")?,
                    params_bin: m.expect_str("params_bin")?.to_string(),
                    params_seed: seed,
                    // On-disk manifests describe compiled HLO; the
                    // reference arch only matters for built-in models.
                    arch: ModelArch::MeanPool,
                    buckets,
                },
            );
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            seed,
            models,
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelArtifacts> {
        self.models
            .get(name)
            .with_context(|| format!("model `{name}` not in manifest"))
    }

    /// Build the in-memory reference manifest: the CPU-scale `tiny`,
    /// `tiny-hstu` and `small` presets with built-in deterministic
    /// parameters and a small ladder of (batch, length) buckets. This is
    /// what [`crate::runtime::Engine::reference`] serves — no files
    /// involved. `tiny-hstu` runs the real HSTU attention blocks in the
    /// reference executor; the others keep the mean-pool dense toy.
    pub fn reference(seed: u64) -> Manifest {
        let mut models = BTreeMap::new();
        for name in ["tiny", "tiny-hstu", "small"] {
            let cfg = crate::config::ModelConfig::by_name(name)
                .expect("reference presets exist");
            let buckets = [(4usize, 32usize), (8, 64), (16, 128), (32, 256)]
                .iter()
                .map(|&(batch, len)| Bucket {
                    batch,
                    len,
                    train: BUILTIN.to_string(),
                    forward: BUILTIN.to_string(),
                })
                .collect();
            models.insert(
                name.to_string(),
                ModelArtifacts {
                    name: name.to_string(),
                    emb_dim: cfg.emb_dim,
                    heads: cfg.hstu_heads,
                    blocks: cfg.hstu_blocks,
                    tasks: cfg.num_tasks,
                    param_count: cfg.dense_params(),
                    params_bin: BUILTIN.to_string(),
                    params_seed: seed,
                    arch: if name == "tiny-hstu" {
                        ModelArch::Hstu
                    } else {
                        ModelArch::MeanPool
                    },
                    buckets,
                },
            );
        }
        Manifest {
            dir: PathBuf::new(),
            seed,
            models,
        }
    }

    /// Default artifacts directory: `$MTGR_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("MTGR_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_fake_manifest(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        let manifest = r#"{
          "version": 1, "seed": 5,
          "models": {
            "demo": {
              "emb_dim": 8, "heads": 2, "blocks": 1, "experts": 2,
              "top_k": 1, "expert_hidden": 8, "tasks": 2,
              "param_count": 3, "params_bin": "demo_params.bin",
              "train_outputs": ["loss_sums","grads","emb_grad","logits","n_valid"],
              "buckets": [
                {"batch": 8, "len": 64, "train": "t2", "forward": "f2"},
                {"batch": 4, "len": 32, "train": "t1", "forward": "f1"}
              ]
            }
          }
        }"#;
        std::fs::write(dir.join("manifest.json"), manifest).unwrap();
        std::fs::write(
            dir.join("demo_params.bin"),
            [1.0f32, 2.0, 3.0]
                .iter()
                .flat_map(|f| f.to_le_bytes())
                .collect::<Vec<u8>>(),
        )
        .unwrap();
    }

    fn tmp(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("mtgr_manifest_{tag}_{}", std::process::id()))
    }

    #[test]
    fn parses_and_sorts_buckets() {
        let dir = tmp("parse");
        write_fake_manifest(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.seed, 5);
        let demo = m.model("demo").unwrap();
        assert_eq!(demo.buckets.len(), 2);
        assert_eq!(demo.buckets[0].batch, 4, "sorted ascending");
        assert_eq!(demo.buckets[0].capacity(), 128);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn bucket_picking() {
        let dir = tmp("pick");
        write_fake_manifest(&dir);
        let m = Manifest::load(&dir).unwrap();
        let demo = m.model("demo").unwrap();
        assert_eq!(demo.pick_bucket(3, 20).unwrap().batch, 4);
        assert_eq!(demo.pick_bucket(4, 33).unwrap().batch, 8);
        assert_eq!(demo.pick_bucket(5, 10).unwrap().batch, 8);
        assert!(demo.pick_bucket(9, 10).is_none());
        assert_eq!(demo.largest_bucket().batch, 8);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn loads_params_with_size_check() {
        let dir = tmp("params");
        write_fake_manifest(&dir);
        let m = Manifest::load(&dir).unwrap();
        let demo = m.model("demo").unwrap();
        assert_eq!(demo.load_params(&dir).unwrap(), vec![1.0, 2.0, 3.0]);
        // Corrupt size → error.
        std::fs::write(dir.join("demo_params.bin"), [0u8; 7]).unwrap();
        assert!(demo.load_params(&dir).is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn unknown_model_errors() {
        let dir = tmp("unknown");
        write_fake_manifest(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert!(m.model("nope").is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn missing_manifest_is_helpful() {
        let err = Manifest::load(Path::new("/nonexistent_dir_xyz")).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
