//! The execution engine.
//!
//! Two backends sit behind one request channel:
//!
//! - **PJRT** (feature `pjrt`): client/executable handles wrap raw
//!   pointers and are not `Send`, so a dedicated engine thread owns them
//!   all; worker threads submit requests through a channel and block on
//!   a reply channel. Executables are compiled lazily per (model,
//!   bucket, kind) and cached — matching a deployment where each model
//!   variant is compiled once per process. Requires the `xla` bindings,
//!   which the offline registry does not carry.
//! - **Reference CPU** (default): [`super::reference`] executes a
//!   deterministic pure-Rust stand-in for the train/forward artifacts,
//!   so the full distributed trainer runs — and is bit-reproducible —
//!   without Python, artifacts, or PJRT. [`Engine::reference`] builds an
//!   engine over an in-memory manifest for exactly this path.
//!
//! Host-side data travels as [`Tensor`] (shape + typed buffer); the
//! engine converts at the backend boundary.

#[cfg(feature = "pjrt")]
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{anyhow, bail, Context, Result};

use super::manifest::{ArtifactKind, Manifest};
use super::reference::TrainScratch;
use crate::util::pool::WorkerPool;

/// A host tensor crossing the engine boundary.
#[derive(Clone, Debug, PartialEq)]
pub enum Tensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl Tensor {
    pub fn f32(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor::F32 {
            shape: shape.to_vec(),
            data,
        }
    }

    pub fn i32(shape: &[usize], data: Vec<i32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor::I32 {
            shape: shape.to_vec(),
            data,
        }
    }

    pub fn scalar_f32(x: f32) -> Tensor {
        Tensor::F32 {
            shape: vec![],
            data: vec![x],
        }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32 { shape, .. } | Tensor::I32 { shape, .. } => shape,
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn first_f32(&self) -> Result<f32> {
        Ok(self.as_f32()?[0])
    }

    #[cfg(feature = "pjrt")]
    fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            Tensor::F32 { data, .. } => xla::Literal::vec1(data),
            Tensor::I32 { data, .. } => xla::Literal::vec1(data),
        };
        Ok(lit.reshape(&dims)?)
    }

    #[cfg(feature = "pjrt")]
    fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(Tensor::F32 {
                shape: dims,
                data: lit.to_vec::<f32>()?,
            }),
            xla::ElementType::S32 => Ok(Tensor::I32 {
                shape: dims,
                data: lit.to_vec::<i32>()?,
            }),
            other => bail!("unsupported output element type {other:?}"),
        }
    }
}

/// Parsed outputs of one train-step execution (the artifact's 5-tuple).
#[derive(Clone, Debug)]
pub struct TrainOutputs {
    /// Per-task loss sums over valid samples, length = tasks.
    pub loss_sums: Vec<f32>,
    /// Flat dense gradient (sum over valid samples), length = P.
    pub grads: Vec<f32>,
    /// Gradient w.r.t. the embedding input, (B, L, D) flattened.
    pub emb_grad: Vec<f32>,
    /// Logits (B, tasks) flattened.
    pub logits: Vec<f32>,
    /// Number of valid (non-padded) samples.
    pub n_valid: f32,
}

struct Request {
    model: String,
    kind: ArtifactKind,
    bucket: (usize, usize),
    inputs: Vec<Tensor>,
    reply: Sender<Result<Vec<Tensor>>>,
}

enum Msg {
    Run(Request),
    Shutdown,
}

/// Cloneable handle to the engine thread.
#[derive(Clone)]
pub struct Engine {
    tx: Sender<Msg>,
    manifest: Arc<Manifest>,
    /// Reference-backend requests execute inline on the calling thread
    /// (the executor is pure, so workers run their dense steps truly in
    /// parallel instead of serializing through the engine channel); the
    /// channel stays for the PJRT backend, whose handles are not Send.
    inline_reference: bool,
    _join: Arc<JoinGuard>,
}

struct JoinGuard {
    tx: Sender<Msg>,
    handle: Option<JoinHandle<()>>,
}

impl Drop for JoinGuard {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Engine {
    /// Start the engine over an artifacts directory (must contain
    /// `manifest.json`; see `make artifacts`).
    pub fn start(dir: &std::path::Path) -> Result<Engine> {
        let manifest = Arc::new(Manifest::load(dir)?);
        let (tx, rx) = channel::<Msg>();
        let dir: PathBuf = dir.to_path_buf();
        let mani2 = Arc::clone(&manifest);
        let handle = std::thread::Builder::new()
            .name("pjrt-engine".into())
            .spawn(move || {
                engine_main(dir, mani2, rx);
            })
            .context("spawn engine thread")?;
        Ok(Engine {
            tx: tx.clone(),
            manifest,
            // Without the `pjrt` feature every artifact executes on the
            // reference backend anyway; skip the channel round-trip.
            inline_reference: cfg!(not(feature = "pjrt")),
            _join: Arc::new(JoinGuard {
                tx,
                handle: Some(handle),
            }),
        })
    }

    /// Start over the default artifacts dir (`$MTGR_ARTIFACTS` or
    /// `./artifacts`).
    pub fn start_default() -> Result<Engine> {
        Engine::start(&Manifest::default_dir())
    }

    /// Start an engine over the in-memory reference manifest (`tiny` and
    /// `small` presets with deterministic built-in parameters), executed
    /// by the pure-Rust reference backend. No artifacts directory, no
    /// Python, no PJRT — the path used by offline tests and CI.
    pub fn reference(seed: u64) -> Result<Engine> {
        let manifest = Arc::new(Manifest::reference(seed));
        let (tx, rx) = channel::<Msg>();
        let mani2 = Arc::clone(&manifest);
        let handle = std::thread::Builder::new()
            .name("reference-engine".into())
            .spawn(move || reference_engine_main(mani2, rx))
            .context("spawn engine thread")?;
        Ok(Engine {
            tx: tx.clone(),
            manifest,
            inline_reference: true,
            _join: Arc::new(JoinGuard {
                tx,
                handle: Some(handle),
            }),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Validate `bucket` exists in `arts` (inline paths skip the engine
    /// thread's own check; takes the already-fetched artifacts so the
    /// hot path does one manifest lookup per call).
    fn ensure_bucket(
        arts: &super::manifest::ModelArtifacts,
        model: &str,
        bucket: (usize, usize),
    ) -> Result<()> {
        anyhow::ensure!(
            arts.buckets.iter().any(|b| (b.batch, b.len) == bucket),
            "no bucket {bucket:?} for model {model}"
        );
        Ok(())
    }

    /// Execute an artifact; blocks until the result is ready.
    /// Thread-safe. Reference-backend engines execute inline on the
    /// calling thread (the executor is pure); the PJRT backend
    /// serializes through the engine thread, as a single shared GPU
    /// would.
    pub fn execute(
        &self,
        model: &str,
        kind: ArtifactKind,
        bucket: (usize, usize),
        inputs: Vec<Tensor>,
    ) -> Result<Vec<Tensor>> {
        if self.inline_reference {
            let arts = self.manifest.model(model)?;
            Self::ensure_bucket(arts, model, bucket)?;
            return super::reference::execute(arts, kind, bucket, &inputs);
        }
        let (reply_tx, reply_rx) = channel();
        self.tx
            .send(Msg::Run(Request {
                model: model.to_string(),
                kind,
                bucket,
                inputs,
                reply: reply_tx,
            }))
            .map_err(|_| anyhow!("engine thread is gone"))?;
        reply_rx
            .recv()
            .map_err(|_| anyhow!("engine dropped the request"))?
    }

    /// Execute a train step and parse the 5-tuple.
    pub fn train_step(
        &self,
        model: &str,
        bucket: (usize, usize),
        params: &[f32],
        emb: Tensor,
        lengths: Vec<i32>,
        labels: Vec<f32>,
    ) -> Result<TrainOutputs> {
        let (b, _l) = bucket;
        let arts = self.manifest.model(model)?;
        anyhow::ensure!(lengths.len() == b, "lengths arity");
        anyhow::ensure!(labels.len() == b * arts.tasks, "labels arity");
        let inputs = vec![
            Tensor::f32(&[arts.param_count], params.to_vec()),
            emb,
            Tensor::i32(&[b], lengths),
            Tensor::f32(&[b, arts.tasks], labels),
        ];
        let mut out = self.execute(model, ArtifactKind::Train, bucket, inputs)?;
        anyhow::ensure!(out.len() == 5, "train artifact returns 5 outputs");
        let n_valid = out.remove(4).first_f32()?;
        let logits = out.remove(3).into_f32()?;
        let emb_grad = out.remove(2).into_f32()?;
        let grads = out.remove(1).into_f32()?;
        let loss_sums = out.remove(0).into_f32()?;
        Ok(TrainOutputs {
            loss_sums,
            grads,
            emb_grad,
            logits,
            n_valid,
        })
    }

    /// Zero-copy train step into a caller-owned scratch arena: the
    /// reference backend executes inline with the batch chunked across
    /// `pool` (bit-identical for every pool size), reading the inputs
    /// as slices and writing the 5-tuple into `scratch` — no per-step
    /// tensor allocation. The PJRT backend falls back to the channel
    /// path and copies the outputs into `scratch`.
    #[allow(clippy::too_many_arguments)]
    pub fn train_step_into(
        &self,
        model: &str,
        bucket: (usize, usize),
        params: &[f32],
        emb: &[f32],
        lengths: &[i32],
        labels: &[f32],
        pool: Option<&WorkerPool>,
        scratch: &mut TrainScratch,
    ) -> Result<()> {
        let (b, l) = bucket;
        let arts = self.manifest.model(model)?;
        anyhow::ensure!(lengths.len() == b, "lengths arity");
        anyhow::ensure!(labels.len() == b * arts.tasks, "labels arity");
        anyhow::ensure!(emb.len() == b * l * arts.emb_dim, "emb arity");
        if self.inline_reference {
            Self::ensure_bucket(arts, model, bucket)?;
            return super::reference::train_into(
                arts, bucket, params, emb, lengths, labels, pool, scratch,
            );
        }
        let out = self.train_step(
            model,
            bucket,
            params,
            Tensor::f32(&[b, l, arts.emb_dim], emb.to_vec()),
            lengths.to_vec(),
            labels.to_vec(),
        )?;
        scratch.loss_sums = out.loss_sums;
        scratch.grads = out.grads;
        scratch.emb_grad = out.emb_grad;
        scratch.logits = out.logits;
        scratch.n_valid = out.n_valid;
        Ok(())
    }

    /// Execute inference forward; returns logits (B × tasks, flattened).
    pub fn forward(
        &self,
        model: &str,
        bucket: (usize, usize),
        params: &[f32],
        emb: Tensor,
        lengths: Vec<i32>,
    ) -> Result<Vec<f32>> {
        let arts = self.manifest.model(model)?;
        let inputs = vec![
            Tensor::f32(&[arts.param_count], params.to_vec()),
            emb,
            Tensor::i32(&[lengths.len()], lengths),
        ];
        let mut out = self.execute(model, ArtifactKind::Forward, bucket, inputs)?;
        anyhow::ensure!(out.len() == 1, "forward artifact returns 1 output");
        out.remove(0).into_f32()
    }
}

/// The engine thread without PJRT: every request executes on the
/// deterministic reference CPU backend ([`super::reference`]).
#[cfg(not(feature = "pjrt"))]
fn engine_main(_dir: PathBuf, manifest: Arc<Manifest>, rx: std::sync::mpsc::Receiver<Msg>) {
    reference_engine_main(manifest, rx);
}

/// Serve requests with the reference executor until shutdown.
fn reference_engine_main(manifest: Arc<Manifest>, rx: std::sync::mpsc::Receiver<Msg>) {
    while let Ok(msg) = rx.recv() {
        let req = match msg {
            Msg::Run(r) => r,
            Msg::Shutdown => break,
        };
        let result = (|| -> Result<Vec<Tensor>> {
            let arts = manifest.model(&req.model)?;
            anyhow::ensure!(
                arts.buckets.iter().any(|b| (b.batch, b.len) == req.bucket),
                "no bucket {:?} for model {}",
                req.bucket,
                req.model
            );
            super::reference::execute(arts, req.kind, req.bucket, &req.inputs)
        })();
        let _ = req.reply.send(result);
    }
}

/// The engine thread: owns the PJRT client + executable cache.
#[cfg(feature = "pjrt")]
fn engine_main(dir: PathBuf, manifest: Arc<Manifest>, rx: std::sync::mpsc::Receiver<Msg>) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => c,
        Err(e) => {
            // Fail every request with the creation error.
            while let Ok(Msg::Run(req)) = rx.recv() {
                let _ = req.reply.send(Err(anyhow!("PJRT client failed: {e}")));
            }
            return;
        }
    };
    let mut cache: HashMap<(String, ArtifactKind, (usize, usize)), xla::PjRtLoadedExecutable> =
        HashMap::new();

    while let Ok(msg) = rx.recv() {
        let req = match msg {
            Msg::Run(r) => r,
            Msg::Shutdown => break,
        };
        let key = (req.model.clone(), req.kind, req.bucket);
        let result = (|| -> Result<Vec<Tensor>> {
            if !cache.contains_key(&key) {
                let arts = manifest.model(&req.model)?;
                let bucket = arts
                    .buckets
                    .iter()
                    .find(|b| (b.batch, b.len) == req.bucket)
                    .with_context(|| {
                        format!("no bucket {:?} for model {}", req.bucket, req.model)
                    })?;
                let path = dir.join(bucket.artifact(req.kind));
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str().context("artifact path utf-8")?,
                )
                .map_err(|e| anyhow!("parse {}: {e}", path.display()))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = client
                    .compile(&comp)
                    .map_err(|e| anyhow!("compile {}: {e}", path.display()))?;
                cache.insert(key.clone(), exe);
            }
            let exe = cache.get(&key).unwrap();
            let literals: Vec<xla::Literal> = req
                .inputs
                .iter()
                .map(|t| t.to_literal())
                .collect::<Result<_>>()?;
            let result = exe
                .execute::<xla::Literal>(&literals)
                .map_err(|e| anyhow!("execute: {e}"))?;
            let tuple = result[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("fetch result: {e}"))?;
            // Artifacts are lowered with return_tuple=True.
            let parts = tuple.to_tuple().map_err(|e| anyhow!("untuple: {e}"))?;
            parts.iter().map(Tensor::from_literal).collect()
        })();
        let _ = req.reply.send(result);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<std::path::PathBuf> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn tensor_shape_checks() {
        let t = Tensor::f32(&[2, 3], vec![0.0; 6]);
        assert_eq!(t.shape(), &[2, 3]);
        assert!(t.as_f32().is_ok());
        let i = Tensor::i32(&[2], vec![1, 2]);
        assert!(i.as_f32().is_err());
    }

    #[test]
    #[should_panic]
    fn tensor_arity_mismatch_panics() {
        let _ = Tensor::f32(&[2, 3], vec![0.0; 5]);
    }

    // The heavier end-to-end engine tests (compile + execute the tiny
    // model, compare against python) live in
    // rust/tests/integration_runtime.rs; this smoke test only runs when
    // artifacts exist.
    #[test]
    fn engine_starts_and_reports_manifest() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let engine = Engine::start(&dir).unwrap();
        assert!(engine.manifest().models.contains_key("tiny"));
        // Unknown bucket errors cleanly through the channel.
        let err = engine
            .execute("tiny", ArtifactKind::Train, (999, 999), vec![])
            .unwrap_err();
        assert!(format!("{err:#}").contains("no bucket"));
    }
}
