//! Synthetic Meituan-like workload generator.
//!
//! Substitutes for the paper's 90 days × 400 M sequences of production
//! logs (DESIGN.md substitution #2). The generator is seeded and
//! reproduces the *distributional* properties the evaluated techniques
//! are sensitive to:
//!
//! - **Sequence lengths**: lognormal long tail with mean ≈ 600 and hard
//!   cap 3 000 (§6.1), the source of GPU load imbalance (Fig. 9/15);
//! - **Item popularity**: Zipf-skewed, driving the intra-batch duplicate
//!   ratio that two-stage dedup exploits (Fig. 16);
//! - **New-ID arrival**: a configurable fraction of each day's users and
//!   items are brand new (merchants updating menus, new users), the case
//!   static tables fail on and dynamic tables handle (§4.1, Table 3);
//! - **Planted labels**: CTR/CTCVR are Bernoulli draws from a hidden
//!   per-user/per-category logit model so the GAUC learning curve of a
//!   trained model is meaningful (Fig. 11).

use super::schema::{Schema, Sequence};
use crate::embedding::hash::hash_id;
use crate::util::rng::{Xoshiro256, Zipf};

/// Generator parameters.
#[derive(Clone, Debug)]
pub struct GeneratorConfig {
    pub seed: u64,
    /// Base populations (day 0).
    pub num_users: u64,
    pub num_items: u64,
    pub num_cates: u64,
    pub num_cities: u64,
    /// Lognormal length distribution (underlying mu/sigma) + clamp.
    pub len_mu: f64,
    pub len_sigma: f64,
    pub min_len: usize,
    pub max_len: usize,
    /// Zipf exponents for user activity and item popularity.
    pub item_zipf: f64,
    /// Fraction of sequences whose user is new *per day index*.
    pub new_user_rate: f64,
    pub new_item_rate: f64,
}

impl Default for GeneratorConfig {
    fn default() -> Self {
        GeneratorConfig {
            seed: 2026,
            num_users: 100_000,
            num_items: 50_000,
            num_cates: 200,
            num_cities: 100,
            // exp(6.2 + 0.72²/2) ≈ 635 mean, long tail, capped at 3000.
            len_mu: 6.2,
            len_sigma: 0.72,
            min_len: 8,
            max_len: 3000,
            item_zipf: 1.05,
            new_user_rate: 0.02,
            new_item_rate: 0.01,
        }
    }
}

/// Hidden planted model: logits are deterministic functions of
/// (user, cate) via hashing, so labels are learnable but not trivially
/// linear in the raw IDs. Three components:
/// - a per-user bias (invisible to GAUC, which ranks within users);
/// - a *global* per-category attractiveness, learnable directly from
///   category embeddings and visible to GAUC (a user's samples differ
///   in category mix);
/// - a smaller user×category interaction term.
fn planted_logit(user: u64, cates: &[u64], seed: u64) -> (f64, f64) {
    let unit = |h: u64| (h % 1000) as f64 / 1000.0 * 2.0 - 1.0;
    let u_bias = unit(hash_id(user, seed ^ 0xA11CE));
    let mut c_glob = 0.0;
    let mut c_pers = 0.0;
    for &c in cates {
        c_glob += unit(hash_id(c, seed ^ 0xC0C0A));
        c_pers += unit(hash_id(c ^ user.rotate_left(17), seed ^ 0xBEE));
    }
    if !cates.is_empty() {
        c_glob /= cates.len() as f64;
        c_pers /= cates.len() as f64;
    }
    let ctr_logit = -1.0 + 1.2 * u_bias + 2.5 * c_glob + 1.0 * c_pers;
    // CTCVR is a harder event correlated with CTR.
    let ctcvr_logit = -2.5 + 1.0 * u_bias + 2.0 * c_glob + 0.8 * c_pers;
    (ctr_logit, ctcvr_logit)
}

fn sigmoid(x: f64) -> f64 {
    1.0 / (1.0 + (-x).exp())
}

/// The workload generator; an infinite, seeded stream of [`Sequence`]s.
pub struct WorkloadGenerator {
    pub cfg: GeneratorConfig,
    rng: Xoshiro256,
    item_zipf: Zipf,
    user_zipf: Zipf,
    /// "Day" index; advancing it introduces new users/items (dynamic IDs).
    day: u64,
    generated: u64,
}

impl WorkloadGenerator {
    pub fn new(cfg: GeneratorConfig) -> Self {
        // Cap the inverse-CDF table sizes: popularity ranks beyond ~100k
        // contribute negligibly and the table is O(n).
        let item_ranks = cfg.num_items.min(200_000) as usize;
        let user_ranks = cfg.num_users.min(200_000) as usize;
        WorkloadGenerator {
            rng: Xoshiro256::new(cfg.seed),
            item_zipf: Zipf::new(item_ranks, cfg.item_zipf),
            user_zipf: Zipf::new(user_ranks, 0.8),
            day: 0,
            generated: 0,
            cfg,
        }
    }

    /// Advance to the next "day": a fresh slice of user/item ID space
    /// opens up (the streaming new-ID arrival of production).
    pub fn advance_day(&mut self) {
        self.day += 1;
    }

    pub fn day(&self) -> u64 {
        self.day
    }

    /// Sample one sequence length from the clamped lognormal.
    fn sample_len(&mut self) -> usize {
        let l = self.rng.lognormal(self.cfg.len_mu, self.cfg.len_sigma) as usize;
        l.clamp(self.cfg.min_len, self.cfg.max_len)
    }

    /// Draw a user id; with probability `new_user_rate` it comes from the
    /// day's fresh range (ids ≥ num_users · (1 + day-fraction)).
    fn sample_user(&mut self) -> u64 {
        if self.day > 0 && self.rng.bernoulli(self.cfg.new_user_rate) {
            // New-user id space for this day.
            self.cfg.num_users + (self.day - 1) * self.cfg.num_users / 50
                + self.rng.gen_range(self.cfg.num_users / 50)
        } else {
            // Zipf rank → id (rank 0 = most active user).
            self.user_zipf.sample(&mut self.rng) as u64
        }
    }

    fn sample_item(&mut self) -> u64 {
        if self.day > 0 && self.rng.bernoulli(self.cfg.new_item_rate) {
            self.cfg.num_items + (self.day - 1) * self.cfg.num_items / 100
                + self.rng.gen_range(self.cfg.num_items / 100)
        } else {
            self.item_zipf.sample(&mut self.rng) as u64
        }
    }

    /// Generate one sequence under `schema`. Feature values are routed
    /// by *name*, so heterogeneous schema presets (e.g.
    /// [`Schema::meituan_mixed`]'s `exp_item_id` alias feature) work
    /// without changing the base draw order: the default schema
    /// consumes exactly the same RNG stream as before (user, length,
    /// then per token item + action), and only features *beyond* the
    /// base set draw extra samples after the base draws of their token.
    pub fn next_sequence(&mut self, schema: &Schema) -> Sequence {
        self.generated += 1;
        let user = self.sample_user();
        let len = self.sample_len();
        let city = hash_id(user, 0xC17) % self.cfg.num_cities;
        let segment = hash_id(user, 0x5E6) % 16;
        let context: Vec<u64> = schema
            .context_features
            .iter()
            .map(|f| match f.name.as_str() {
                "user_id" => user,
                "user_city" => city,
                "user_segment" => segment,
                other => panic!("generator does not know context feature `{other}`"),
            })
            .collect();

        let mut tokens = Vec::with_capacity(len);
        let mut cates = Vec::with_capacity(len);
        for t in 0..len {
            let item = self.sample_item();
            let cate = hash_id(item, 0xCA7E) % self.cfg.num_cates;
            cates.push(cate);
            let action = self.rng.gen_range(4); // click/order/fav/view
            let hour = (hash_id(user, 0x40) + t as u64 / 8) % 24;
            let mut tok = Vec::with_capacity(schema.num_token_features());
            for f in &schema.token_features {
                let v = match f.name.as_str() {
                    "item_id" => item,
                    "cate_id" => cate,
                    "action_type" => action,
                    "hour_of_day" => hour,
                    // Real-time exposure item: an independent draw from
                    // the same item popularity distribution (it aliases
                    // the item table in the merge plan).
                    "exp_item_id" => self.sample_item(),
                    other => panic!("generator does not know token feature `{other}`"),
                };
                tok.push(v);
            }
            tokens.push(tok);
        }

        let (lc, lv) = planted_logit(user, &cates, self.cfg.seed);
        let ctr = self.rng.bernoulli(sigmoid(lc)) as u64 as f32;
        // CTCVR can only fire if CTR fired (conversion after click).
        let ctcvr = if ctr > 0.0 {
            self.rng.bernoulli(sigmoid(lv)) as u64 as f32
        } else {
            0.0
        };
        Sequence {
            user_id: user,
            context,
            tokens,
            labels: [ctr, ctcvr],
        }
    }

    /// Generate a batch of sequences.
    pub fn batch(&mut self, schema: &Schema, n: usize) -> Vec<Sequence> {
        (0..n).map(|_| self.next_sequence(schema)).collect()
    }

    pub fn generated(&self) -> u64 {
        self.generated
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::Summary;

    fn schema() -> Schema {
        Schema::meituan_like(8, 1)
    }

    #[test]
    fn deterministic_given_seed() {
        let s = schema();
        let mut g1 = WorkloadGenerator::new(GeneratorConfig::default());
        let mut g2 = WorkloadGenerator::new(GeneratorConfig::default());
        for _ in 0..20 {
            assert_eq!(g1.next_sequence(&s), g2.next_sequence(&s));
        }
    }

    #[test]
    fn length_distribution_matches_paper() {
        let s = schema();
        let mut g = WorkloadGenerator::new(GeneratorConfig::default());
        let lens: Vec<f64> = (0..5000)
            .map(|_| g.next_sequence(&s).len() as f64)
            .collect();
        let sum = Summary::of(&lens);
        assert!(
            (450.0..800.0).contains(&sum.mean),
            "mean length ≈ 600, got {:.0}",
            sum.mean
        );
        assert!(sum.max <= 3000.0);
        assert!(sum.max > 2000.0, "long tail reaches the cap");
        assert!(sum.p50 < sum.mean, "right-skewed");
    }

    #[test]
    fn mixed_schema_emits_exposure_items_deterministically() {
        let s = Schema::meituan_mixed(32);
        let mut g1 = WorkloadGenerator::new(GeneratorConfig::default());
        let mut g2 = WorkloadGenerator::new(GeneratorConfig::default());
        for _ in 0..10 {
            let a = g1.next_sequence(&s);
            let b = g2.next_sequence(&s);
            assert_eq!(a, b);
            assert_eq!(a.context.len(), 3);
            for tok in &a.tokens {
                assert_eq!(tok.len(), 5, "5 token features incl. exp_item_id");
                assert!(
                    tok[4] < GeneratorConfig::default().num_items,
                    "day-0 exposure items come from the base item space"
                );
            }
        }
        // The exposure draw is independent of the history item draw.
        let some_differ = (0..20).any(|_| {
            let seq = g1.next_sequence(&s);
            seq.tokens.iter().any(|t| t[0] != t[4])
        });
        assert!(some_differ, "exp_item_id must not mirror item_id");
    }

    #[test]
    fn item_ids_are_zipf_skewed() {
        let s = schema();
        let mut g = WorkloadGenerator::new(GeneratorConfig::default());
        let mut counts = std::collections::HashMap::new();
        for _ in 0..50 {
            let seq = g.next_sequence(&s);
            for t in &seq.tokens {
                *counts.entry(t[0]).or_insert(0usize) += 1;
            }
        }
        let total: usize = counts.values().sum();
        let max = *counts.values().max().unwrap();
        // Head item should take a disproportionate share.
        assert!(
            max as f64 / total as f64 > 0.01,
            "zipf head share too small"
        );
    }

    #[test]
    fn new_ids_appear_on_later_days() {
        let s = schema();
        let cfg = GeneratorConfig {
            new_user_rate: 0.5,
            new_item_rate: 0.5,
            ..Default::default()
        };
        let base_users = cfg.num_users;
        let mut g = WorkloadGenerator::new(cfg);
        // Day 0: no new ids.
        for _ in 0..100 {
            assert!(g.next_sequence(&s).user_id < base_users);
        }
        g.advance_day();
        let mut saw_new = false;
        for _ in 0..100 {
            if g.next_sequence(&s).user_id >= base_users {
                saw_new = true;
            }
        }
        assert!(saw_new, "day 1 must mint new user ids");
    }

    #[test]
    fn labels_correlate_with_planted_model() {
        // The empirical CTR among users with high planted logits must
        // exceed that among low-logit users → the signal is learnable.
        let s = schema();
        let mut g = WorkloadGenerator::new(GeneratorConfig::default());
        let (mut hi, mut hi_n, mut lo, mut lo_n) = (0.0, 0, 0.0, 0);
        for _ in 0..3000 {
            let seq = g.next_sequence(&s);
            let cates: Vec<u64> = seq.tokens.iter().map(|t| t[1]).collect();
            let (logit, _) = planted_logit(seq.user_id, &cates, 2026);
            if logit > 0.0 {
                hi += seq.labels[0] as f64;
                hi_n += 1;
            } else {
                lo += seq.labels[0] as f64;
                lo_n += 1;
            }
        }
        let hi_rate = hi / hi_n.max(1) as f64;
        let lo_rate = lo / lo_n.max(1) as f64;
        assert!(
            hi_rate > lo_rate + 0.2,
            "planted signal too weak: {hi_rate:.2} vs {lo_rate:.2}"
        );
    }

    #[test]
    fn ctcvr_implies_ctr() {
        let s = schema();
        let mut g = WorkloadGenerator::new(GeneratorConfig::default());
        for _ in 0..2000 {
            let seq = g.next_sequence(&s);
            if seq.labels[1] > 0.0 {
                assert_eq!(seq.labels[0], 1.0, "conversion without click");
            }
        }
    }
}
