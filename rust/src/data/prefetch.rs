//! Prefetch pipeline (§3 "Pipeline"): overlap batch loading with
//! computation.
//!
//! "We prefetch multiple next batches and overlap their loading with the
//! computation of the current batch, thereby masking I/O latency." The
//! paper runs three streams — copy, dispatch, compute; here the *copy*
//! stream is a background producer thread feeding a bounded channel
//! (depth = number of prefetched batches), and *dispatch*/*compute*
//! belong to the trainer. [`Prefetcher`] is generic so it also pipelines
//! shard reads, generated batches, or balanced batches.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;

/// A background producer with a bounded prefetch queue.
///
/// Shutdown is drop-based and leak-free: dropping the prefetcher drains
/// the queue, closes the channel (unblocking a producer parked on a
/// full buffer), and **joins** the producer thread — no detached thread
/// outlives the consumer.
pub struct Prefetcher<T: Send + 'static> {
    rx: Receiver<T>,
    handle: Option<JoinHandle<()>>,
    /// Number of items delivered so far.
    delivered: usize,
    /// Items the producer has pushed into the queue so far.
    produced: Arc<AtomicUsize>,
    depth: usize,
    /// Sum over `next()` calls of the queue occupancy observed at call
    /// time (how many batches were ready when the consumer asked — the
    /// I/O-masking figure surfaced as `depth_occupancy`).
    occ_sum: usize,
    occ_samples: usize,
}

impl<T: Send + 'static> Prefetcher<T> {
    /// Spawn the producer. `produce()` returns `None` at end of stream.
    /// `depth` is the number of batches buffered ahead of the consumer.
    pub fn spawn(depth: usize, mut produce: impl FnMut() -> Option<T> + Send + 'static) -> Self {
        assert!(depth >= 1);
        let (tx, rx) = sync_channel(depth);
        let produced = Arc::new(AtomicUsize::new(0));
        let produced_tx = Arc::clone(&produced);
        let handle = std::thread::spawn(move || {
            while let Some(item) = produce() {
                if tx.send(item).is_err() {
                    break; // consumer dropped
                }
                produced_tx.fetch_add(1, Ordering::Release);
            }
        });
        Prefetcher {
            rx,
            handle: Some(handle),
            delivered: 0,
            produced,
            depth,
            occ_sum: 0,
            occ_samples: 0,
        }
    }

    /// Record the queue depth visible to the consumer right now.
    fn sample_occupancy(&mut self) {
        let ready = self
            .produced
            .load(Ordering::Acquire)
            .saturating_sub(self.delivered)
            .min(self.depth);
        self.occ_sum += ready;
        self.occ_samples += 1;
    }

    /// Blocking fetch of the next batch; `None` at end of stream.
    pub fn next(&mut self) -> Option<T> {
        self.sample_occupancy();
        match self.rx.recv() {
            Ok(v) => {
                self.delivered += 1;
                Some(v)
            }
            Err(_) => None,
        }
    }

    /// Non-blocking poll (used to check overlap in tests/benches).
    pub fn try_next(&mut self) -> Option<T> {
        self.sample_occupancy();
        match self.rx.try_recv() {
            Ok(v) => {
                self.delivered += 1;
                Some(v)
            }
            Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => None,
        }
    }

    pub fn delivered(&self) -> usize {
        self.delivered
    }

    /// Configured queue depth.
    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Mean queue occupancy (0.. = `depth`) observed at fetch time: how
    /// many batches the producer had ready when the consumer asked.
    /// Near `depth` means I/O is fully masked; near 0 means the
    /// consumer is starved by the producer.
    pub fn depth_occupancy(&self) -> f64 {
        if self.occ_samples == 0 {
            0.0
        } else {
            self.occ_sum as f64 / self.occ_samples as f64
        }
    }
}

impl<T: Send + 'static> Drop for Prefetcher<T> {
    fn drop(&mut self) {
        // Disconnect first: dropping the receiver makes any parked or
        // future `send` fail immediately — no drain race against a fast
        // endless producer — then join so no thread leaks.
        drop(std::mem::replace(&mut self.rx, sync_channel(1).1));
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl<T: Send + 'static> Iterator for Prefetcher<T> {
    type Item = T;

    fn next(&mut self) -> Option<T> {
        Prefetcher::next(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;
    use std::time::Duration;

    #[test]
    fn delivers_in_order_and_terminates() {
        let mut i = 0;
        let mut p = Prefetcher::spawn(2, move || {
            i += 1;
            if i <= 5 {
                Some(i)
            } else {
                None
            }
        });
        let got: Vec<i32> = std::iter::from_fn(|| p.next()).collect();
        assert_eq!(got, vec![1, 2, 3, 4, 5]);
        assert_eq!(p.delivered(), 5);
    }

    #[test]
    fn producer_runs_ahead_of_consumer() {
        let produced = Arc::new(AtomicUsize::new(0));
        let p2 = Arc::clone(&produced);
        let mut i = 0;
        let mut p = Prefetcher::spawn(3, move || {
            i += 1;
            if i <= 10 {
                p2.fetch_add(1, Ordering::SeqCst);
                Some(i)
            } else {
                None
            }
        });
        // Give the producer time to fill the prefetch buffer before any
        // consumption — the I/O-masking property.
        std::thread::sleep(Duration::from_millis(50));
        let ahead = produced.load(Ordering::SeqCst);
        assert!(ahead >= 3, "expected ≥3 prefetched, got {ahead}");
        assert!(ahead <= 4, "bounded: buffer(3) + 1 in-flight, got {ahead}");
        let _ = p.next();
    }

    #[test]
    fn drop_unblocks_producer() {
        // Producer wants to emit far more than the buffer; dropping the
        // prefetcher must not deadlock.
        let mut i = 0u64;
        let p = Prefetcher::spawn(1, move || {
            i += 1;
            if i < 1_000_000 {
                Some(i)
            } else {
                None
            }
        });
        drop(p); // must return promptly
    }

    #[test]
    fn depth_occupancy_tracks_readiness() {
        // Fast producer, slow consumer: after the producer has had time
        // to fill the buffer, the FIRST fetch must observe a (nearly)
        // full queue. Only that first sample is asserted — later
        // occupancies depend on scheduling and stay unasserted so the
        // test cannot flake on loaded runners.
        let mut i = 0;
        let mut p = Prefetcher::spawn(3, move || {
            i += 1;
            if i <= 20 {
                Some(i)
            } else {
                None
            }
        });
        std::thread::sleep(Duration::from_millis(200));
        assert_eq!(p.next(), Some(1));
        assert_eq!(p.depth(), 3);
        let occ = p.depth_occupancy(); // one sample so far: the mean IS it
        assert!(occ >= 2.0, "expected a mostly-full queue, got {occ:.2}");
        assert!(occ <= 3.0, "occupancy is bounded by depth, got {occ:.2}");
        // Drain the rest; the meter keeps counting samples.
        while p.next().is_some() {}
        assert_eq!(p.delivered(), 20);
        assert!(p.depth_occupancy() <= 3.0);
    }

    #[test]
    fn depth_occupancy_is_zero_before_any_fetch() {
        // Zero-sample edge: a fresh prefetcher has recorded no fetch
        // samples, so the mean must be a well-defined 0.0 — not NaN
        // from a 0/0 division — because callers feed it straight into
        // JSON reports.
        let mut i = 0;
        let p = Prefetcher::spawn(2, move || {
            i += 1;
            if i <= 3 {
                Some(i)
            } else {
                None
            }
        });
        let occ = p.depth_occupancy();
        assert!(occ.is_finite(), "zero-sample occupancy must be finite");
        assert_eq!(occ, 0.0);
        assert_eq!(p.delivered(), 0);
    }

    #[test]
    fn iterator_interface() {
        let mut i = 0;
        let p = Prefetcher::spawn(2, move || {
            i += 1;
            if i <= 3 {
                Some(i * 10)
            } else {
                None
            }
        });
        let v: Vec<i32> = p.collect();
        assert_eq!(v, vec![10, 20, 30]);
    }
}
