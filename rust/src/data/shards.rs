//! Columnar shard format — the partitioned-Hive-on-HDFS substitute.
//!
//! §3: "training data is stored in partitioned Hive tables on HDFS, which
//! utilizes a columnar storage format ... partitioned into smaller shards
//! distributed across devices, which read data in parallel from their
//! assigned shards."
//!
//! Layout (little-endian):
//! ```text
//! magic "MTGR" | version u32 | n_sequences u64 | n_columns u32
//! column directory: n_columns × { name_len u32, name bytes,
//!                                 offset u64, byte_len u64, kind u8 }
//! column payloads (back to back)
//! ```
//! Columns: `user_id` (u64/seq), `seq_len` (u32/seq), `labels`
//! (f32 ×2/seq), one u64 column per context feature, and one *jagged*
//! u64 column per token feature (lengths given by `seq_len`).

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::Path;

use super::schema::{Schema, Sequence};
use anyhow::{bail, Context, Result};

const MAGIC: &[u8; 4] = b"MTGR";
const VERSION: u32 = 1;

const KIND_U64: u8 = 0;
const KIND_U32: u8 = 1;
const KIND_F32: u8 = 2;

struct ColumnMeta {
    name: String,
    offset: u64,
    byte_len: u64,
    kind: u8,
}

/// Write a batch of sequences as one columnar shard file.
pub struct ShardWriter;

impl ShardWriter {
    pub fn write(path: &Path, schema: &Schema, seqs: &[Sequence]) -> Result<()> {
        // Assemble columns in memory (shards are bounded-size by design).
        let n = seqs.len();
        let mut columns: Vec<(String, u8, Vec<u8>)> = Vec::new();

        let mut user_ids = Vec::with_capacity(n * 8);
        let mut seq_lens = Vec::with_capacity(n * 4);
        let mut labels = Vec::with_capacity(n * 8);
        for s in seqs {
            user_ids.extend_from_slice(&s.user_id.to_le_bytes());
            seq_lens.extend_from_slice(&(s.len() as u32).to_le_bytes());
            labels.extend_from_slice(&s.labels[0].to_le_bytes());
            labels.extend_from_slice(&s.labels[1].to_le_bytes());
        }
        columns.push(("user_id".into(), KIND_U64, user_ids));
        columns.push(("seq_len".into(), KIND_U32, seq_lens));
        columns.push(("labels".into(), KIND_F32, labels));

        for (ci, f) in schema.context_features.iter().enumerate() {
            let mut col = Vec::with_capacity(n * 8);
            for s in seqs {
                col.extend_from_slice(&s.context[ci].to_le_bytes());
            }
            columns.push((format!("ctx:{}", f.name), KIND_U64, col));
        }
        for (fi, f) in schema.token_features.iter().enumerate() {
            let mut col = Vec::new();
            for s in seqs {
                for tok in &s.tokens {
                    col.extend_from_slice(&tok[fi].to_le_bytes());
                }
            }
            columns.push((format!("tok:{}", f.name), KIND_U64, col));
        }

        let mut w = BufWriter::new(File::create(path).context("create shard")?);
        w.write_all(MAGIC)?;
        w.write_all(&VERSION.to_le_bytes())?;
        w.write_all(&(n as u64).to_le_bytes())?;
        w.write_all(&(columns.len() as u32).to_le_bytes())?;

        // Directory size must be known to compute payload offsets.
        let dir_size: u64 = columns
            .iter()
            .map(|(name, _, _)| 4 + name.len() as u64 + 8 + 8 + 1)
            .sum();
        let mut offset = 4 + 4 + 8 + 4 + dir_size;
        for (name, kind, payload) in &columns {
            w.write_all(&(name.len() as u32).to_le_bytes())?;
            w.write_all(name.as_bytes())?;
            w.write_all(&offset.to_le_bytes())?;
            w.write_all(&(payload.len() as u64).to_le_bytes())?;
            w.write_all(&[*kind])?;
            offset += payload.len() as u64;
        }
        for (_, _, payload) in &columns {
            w.write_all(payload)?;
        }
        w.flush()?;
        Ok(())
    }
}

/// Columnar shard reader (column-selective, like a real columnar store).
pub struct ShardReader {
    file: BufReader<File>,
    n_sequences: u64,
    columns: Vec<ColumnMeta>,
}

impl ShardReader {
    pub fn open(path: &Path) -> Result<ShardReader> {
        let mut file = BufReader::new(File::open(path).context("open shard")?);
        let mut magic = [0u8; 4];
        file.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("not a MTGR shard: bad magic");
        }
        let version = read_u32(&mut file)?;
        if version != VERSION {
            bail!("unsupported shard version {version}");
        }
        let n_sequences = read_u64(&mut file)?;
        let n_columns = read_u32(&mut file)?;
        let mut columns = Vec::with_capacity(n_columns as usize);
        for _ in 0..n_columns {
            let name_len = read_u32(&mut file)? as usize;
            let mut name = vec![0u8; name_len];
            file.read_exact(&mut name)?;
            let offset = read_u64(&mut file)?;
            let byte_len = read_u64(&mut file)?;
            let mut kind = [0u8; 1];
            file.read_exact(&mut kind)?;
            columns.push(ColumnMeta {
                name: String::from_utf8(name).context("column name")?,
                offset,
                byte_len,
                kind: kind[0],
            });
        }
        Ok(ShardReader {
            file,
            n_sequences,
            columns,
        })
    }

    pub fn num_sequences(&self) -> u64 {
        self.n_sequences
    }

    pub fn column_names(&self) -> Vec<&str> {
        self.columns.iter().map(|c| c.name.as_str()).collect()
    }

    fn read_column_bytes(&mut self, name: &str) -> Result<Vec<u8>> {
        let meta = self
            .columns
            .iter()
            .find(|c| c.name == name)
            .with_context(|| format!("missing column `{name}`"))?;
        let (offset, byte_len) = (meta.offset, meta.byte_len);
        self.file.seek(SeekFrom::Start(offset))?;
        let mut buf = vec![0u8; byte_len as usize];
        self.file.read_exact(&mut buf)?;
        Ok(buf)
    }

    pub fn read_u64_column(&mut self, name: &str) -> Result<Vec<u64>> {
        let meta = self.columns.iter().find(|c| c.name == name);
        if let Some(m) = meta {
            if m.kind != KIND_U64 {
                bail!("column `{name}` is not u64");
            }
        }
        let bytes = self.read_column_bytes(name)?;
        Ok(bytes
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    pub fn read_u32_column(&mut self, name: &str) -> Result<Vec<u32>> {
        let bytes = self.read_column_bytes(name)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    pub fn read_f32_column(&mut self, name: &str) -> Result<Vec<f32>> {
        let bytes = self.read_column_bytes(name)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    /// Reassemble full sequences (row view over the columnar data).
    pub fn read_all(&mut self, schema: &Schema) -> Result<Vec<Sequence>> {
        let user_ids = self.read_u64_column("user_id")?;
        let seq_lens = self.read_u32_column("seq_len")?;
        let labels = self.read_f32_column("labels")?;
        let ctx_cols: Vec<Vec<u64>> = schema
            .context_features
            .iter()
            .map(|f| self.read_u64_column(&format!("ctx:{}", f.name)))
            .collect::<Result<_>>()?;
        let tok_cols: Vec<Vec<u64>> = schema
            .token_features
            .iter()
            .map(|f| self.read_u64_column(&format!("tok:{}", f.name)))
            .collect::<Result<_>>()?;

        let n = self.n_sequences as usize;
        let mut out = Vec::with_capacity(n);
        let mut tok_off = 0usize;
        for i in 0..n {
            let len = seq_lens[i] as usize;
            let context: Vec<u64> = ctx_cols.iter().map(|c| c[i]).collect();
            let mut tokens = Vec::with_capacity(len);
            for t in 0..len {
                tokens.push(tok_cols.iter().map(|c| c[tok_off + t]).collect());
            }
            tok_off += len;
            out.push(Sequence {
                user_id: user_ids[i],
                context,
                tokens,
                labels: [labels[2 * i], labels[2 * i + 1]],
            });
        }
        Ok(out)
    }
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

/// Write a dataset as `num_shards` shard files under `dir`
/// (`shard_00000.mtgr`, ...), the partitioned layout devices read in
/// parallel. Returns the file paths.
pub fn write_sharded_dataset(
    dir: &Path,
    schema: &Schema,
    seqs: &[Sequence],
    num_shards: usize,
) -> Result<Vec<std::path::PathBuf>> {
    std::fs::create_dir_all(dir)?;
    let mut paths = Vec::with_capacity(num_shards);
    for s in 0..num_shards {
        // Round-robin partitioning.
        let part: Vec<Sequence> = seqs
            .iter()
            .skip(s)
            .step_by(num_shards)
            .cloned()
            .collect();
        let path = dir.join(format!("shard_{s:05}.mtgr"));
        ShardWriter::write(&path, schema, &part)?;
        paths.push(path);
    }
    Ok(paths)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generator::{GeneratorConfig, WorkloadGenerator};

    fn tmpdir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("mtgr_shard_test_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn roundtrip_exact() {
        let schema = Schema::meituan_like(8, 1);
        let mut gen = WorkloadGenerator::new(GeneratorConfig {
            len_mu: 3.0, // short sequences for test speed
            ..Default::default()
        });
        let seqs = gen.batch(&schema, 50);
        let dir = tmpdir("rt");
        let path = dir.join("x.mtgr");
        ShardWriter::write(&path, &schema, &seqs).unwrap();
        let mut reader = ShardReader::open(&path).unwrap();
        assert_eq!(reader.num_sequences(), 50);
        let back = reader.read_all(&schema).unwrap();
        assert_eq!(back, seqs);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn column_selective_read() {
        let schema = Schema::meituan_like(8, 1);
        let mut gen = WorkloadGenerator::new(GeneratorConfig {
            len_mu: 3.0,
            ..Default::default()
        });
        let seqs = gen.batch(&schema, 10);
        let dir = tmpdir("col");
        let path = dir.join("x.mtgr");
        ShardWriter::write(&path, &schema, &seqs).unwrap();
        let mut reader = ShardReader::open(&path).unwrap();
        // Read just one column — the columnar advantage.
        let lens = reader.read_u32_column("seq_len").unwrap();
        assert_eq!(lens.len(), 10);
        for (l, s) in lens.iter().zip(&seqs) {
            assert_eq!(*l as usize, s.len());
        }
        // Column list includes all features.
        assert!(reader.column_names().contains(&"tok:item_id"));
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn sharded_dataset_partitions_everything() {
        let schema = Schema::meituan_like(8, 1);
        let mut gen = WorkloadGenerator::new(GeneratorConfig {
            len_mu: 3.0,
            ..Default::default()
        });
        let seqs = gen.batch(&schema, 41);
        let dir = tmpdir("part");
        let paths = write_sharded_dataset(&dir, &schema, &seqs, 4).unwrap();
        assert_eq!(paths.len(), 4);
        let mut total = 0;
        for p in &paths {
            let mut r = ShardReader::open(p).unwrap();
            total += r.read_all(&schema).unwrap().len();
        }
        assert_eq!(total, 41);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn rejects_corrupt_files() {
        let dir = tmpdir("bad");
        let path = dir.join("bad.mtgr");
        std::fs::write(&path, b"not a shard at all").unwrap();
        assert!(ShardReader::open(&path).is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn missing_column_errors() {
        let schema = Schema::meituan_like(8, 1);
        let seqs = vec![Sequence {
            user_id: 1,
            context: vec![1, 2, 3],
            tokens: vec![vec![1, 2, 3, 4]],
            labels: [0.0, 0.0],
        }];
        let dir = tmpdir("miss");
        let path = dir.join("x.mtgr");
        ShardWriter::write(&path, &schema, &seqs).unwrap();
        let mut r = ShardReader::open(&path).unwrap();
        assert!(r.read_u64_column("ctx:nonexistent").is_err());
        std::fs::remove_dir_all(dir).ok();
    }
}
