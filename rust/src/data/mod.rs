//! Data substrate — the Hive/HDFS + production-log substitute.
//!
//! - [`schema`] — the feature schema: contextual (user), historical and
//!   exposure token features (§2's T = [T_con, T_hst, T_exp]).
//! - [`generator`] — seeded synthetic Meituan-like workload reproducing
//!   the statistics the paper's techniques are sensitive to: long-tail
//!   lognormal sequence lengths (mean ≈ 600, max 3 000), Zipf-skewed item
//!   popularity (the dedup win), streaming new-ID arrival (the dynamic
//!   table win) and planted-logit labels (so GAUC learning curves are
//!   meaningful).
//! - [`shards`] — a columnar binary shard format with a column directory
//!   (the partitioned-Hive-table substitute) plus writer/reader.
//! - [`prefetch`] — bounded-channel pipeline used to overlap batch
//!   loading with compute (§3's copy/dispatch/compute streams).

pub mod generator;
pub mod prefetch;
pub mod schema;
pub mod shards;

pub use generator::{GeneratorConfig, WorkloadGenerator};
pub use schema::{Schema, Sequence};
pub use shards::{ShardReader, ShardWriter};
