//! Feature schema and the in-memory sequence sample.
//!
//! A GRM input sequence (§2) is `T = [T_con, T_hst, T_exp]`: contextual
//! (user) features, historical action tokens, and real-time exposure
//! tokens. Each token carries several categorical features (item, cate,
//! action type, ...); the schema names them and maps them onto the
//! [`crate::embedding::merge::FeatureConfig`] declarations that drive
//! automatic table merging.

use crate::embedding::merge::FeatureConfig;
use crate::embedding::FeatureId;

/// Declarative schema: context features (one value per sequence) and
/// token features (one value per token).
#[derive(Clone, Debug)]
pub struct Schema {
    pub context_features: Vec<FeatureConfig>,
    pub token_features: Vec<FeatureConfig>,
}

impl Schema {
    /// The default Meituan-like schema. `dim_factor` scales every
    /// embedding dim (the paper's 1D/8D/64D axis). All token features
    /// share the model embedding dim so pooled token embeddings sum to
    /// one vector per token.
    pub fn meituan_like(emb_dim: usize, dim_factor: usize) -> Schema {
        let d = emb_dim * dim_factor;
        Schema {
            context_features: vec![
                FeatureConfig::new("user_id", d),
                FeatureConfig::new("user_city", d),
                FeatureConfig::new("user_segment", d),
            ],
            token_features: vec![
                FeatureConfig::new("item_id", d),
                FeatureConfig::new("cate_id", d),
                FeatureConfig::new("action_type", d),
                FeatureConfig::new("hour_of_day", d),
            ],
        }
    }

    /// All features, context first (the order used by merged lookups).
    pub fn all_features(&self) -> Vec<FeatureConfig> {
        let mut v = self.context_features.clone();
        v.extend(self.token_features.clone());
        v
    }

    pub fn num_token_features(&self) -> usize {
        self.token_features.len()
    }

    pub fn num_context_features(&self) -> usize {
        self.context_features.len()
    }
}

/// One user sequence sample.
#[derive(Clone, Debug, PartialEq)]
pub struct Sequence {
    pub user_id: u64,
    /// Context feature values, aligned with `schema.context_features`.
    pub context: Vec<FeatureId>,
    /// Token-major feature values: `tokens[t]` aligned with
    /// `schema.token_features`.
    pub tokens: Vec<Vec<FeatureId>>,
    /// Per-sequence labels: [ctr, ctcvr] ∈ {0,1}.
    pub labels: [f32; 2],
}

impl Sequence {
    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    /// All feature ids of this sequence in (feature, occurrence) order:
    /// context first, then token features column-major per token.
    /// Returns (feature_name_index_into_all_features, id) pairs.
    pub fn flat_ids(&self, schema: &Schema) -> Vec<(usize, FeatureId)> {
        let mut out = Vec::with_capacity(
            self.context.len() + self.tokens.len() * schema.num_token_features(),
        );
        for (f, &id) in self.context.iter().enumerate() {
            out.push((f, id));
        }
        let base = schema.num_context_features();
        for tok in &self.tokens {
            for (f, &id) in tok.iter().enumerate() {
                out.push((base + f, id));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_schema_shape() {
        let s = Schema::meituan_like(16, 1);
        assert_eq!(s.num_context_features(), 3);
        assert_eq!(s.num_token_features(), 4);
        assert_eq!(s.all_features().len(), 7);
        for f in s.all_features() {
            assert_eq!(f.dim, 16);
        }
    }

    #[test]
    fn dim_factor_scales_dims() {
        let s = Schema::meituan_like(16, 8);
        for f in s.all_features() {
            assert_eq!(f.dim, 128);
        }
    }

    #[test]
    fn flat_ids_layout() {
        let schema = Schema::meituan_like(8, 1);
        let seq = Sequence {
            user_id: 1,
            context: vec![10, 20, 30],
            tokens: vec![vec![1, 2, 3, 4], vec![5, 6, 7, 8]],
            labels: [1.0, 0.0],
        };
        let flat = seq.flat_ids(&schema);
        assert_eq!(flat.len(), 3 + 2 * 4);
        assert_eq!(flat[0], (0, 10));
        assert_eq!(flat[3], (3, 1)); // first token feature
        assert_eq!(flat[10], (6, 8)); // last token, last feature
    }
}
