//! Feature schema and the in-memory sequence sample.
//!
//! A GRM input sequence (§2) is `T = [T_con, T_hst, T_exp]`: contextual
//! (user) features, historical action tokens, and real-time exposure
//! tokens. Each token carries several categorical features (item, cate,
//! action type, ...); the schema names them and maps them onto the
//! [`crate::embedding::merge::FeatureConfig`] declarations that drive
//! automatic table merging.

use crate::embedding::merge::FeatureConfig;
use crate::embedding::FeatureId;

/// Context-feature embedding dim of the heterogeneous
/// [`Schema::meituan_mixed`] preset (clamped to the model dim).
pub const MIXED_CONTEXT_DIM: usize = 8;

/// Declarative schema: context features (one value per sequence) and
/// token features (one value per token).
#[derive(Clone, Debug)]
pub struct Schema {
    pub context_features: Vec<FeatureConfig>,
    pub token_features: Vec<FeatureConfig>,
}

impl Schema {
    /// The default Meituan-like schema. `dim_factor` scales every
    /// embedding dim (the paper's 1D/8D/64D axis). All token features
    /// share the model embedding dim so pooled token embeddings sum to
    /// one vector per token.
    pub fn meituan_like(emb_dim: usize, dim_factor: usize) -> Schema {
        let d = emb_dim * dim_factor;
        Schema {
            context_features: vec![
                FeatureConfig::new("user_id", d),
                FeatureConfig::new("user_city", d),
                FeatureConfig::new("user_segment", d),
            ],
            token_features: vec![
                FeatureConfig::new("item_id", d),
                FeatureConfig::new("cate_id", d),
                FeatureConfig::new("action_type", d),
                FeatureConfig::new("hour_of_day", d),
            ],
        }
    }

    /// Heterogeneous-dim Meituan-like schema: low-dim (8D) context
    /// features, model-dim token features, and an exposure-item token
    /// feature that *aliases* the history item table (`shared_table`).
    /// [`crate::embedding::merge::MergePlan`] folds this into two merge
    /// groups (one per dim), so the full distributed path — dedup,
    /// exchange, gather/scatter, optimizer, checkpoints — runs at two
    /// physical widths. Rows narrower than the model dim pool into the
    /// *leading* components of the token embedding (zero-extension);
    /// gradients mirror that truncation exactly.
    pub fn meituan_mixed(emb_dim: usize) -> Schema {
        let d = emb_dim;
        let d_ctx = MIXED_CONTEXT_DIM.min(d);
        Schema {
            context_features: vec![
                FeatureConfig::new("user_id", d_ctx),
                FeatureConfig::new("user_city", d_ctx),
                FeatureConfig::new("user_segment", d_ctx),
            ],
            token_features: vec![
                FeatureConfig::new("item_id", d),
                FeatureConfig::new("cate_id", d),
                FeatureConfig::new("action_type", d),
                FeatureConfig::new("hour_of_day", d),
                FeatureConfig::new("exp_item_id", d).shared("item_id"),
            ],
        }
    }

    /// Three-tier multi-tenant schema (the paper's 1D/8D/64D axis made
    /// literal, scaled to the model dim): scalar-ish 1D features
    /// (segments, action types, hour-of-day), mid-dim (8D, clamped)
    /// id-adjacent features, and full model-dim item/user tables, with
    /// the exposure alias kept from [`Schema::meituan_mixed`].
    /// [`crate::embedding::merge::MergePlan`] folds this into **three**
    /// merge groups — one physical table/optimizer/exchange stack per
    /// tier — which is what the `multi-tenant` scenario's per-group
    /// capacity budgets press on.
    pub fn meituan_tiered(emb_dim: usize) -> Schema {
        let d = emb_dim;
        let d_mid = MIXED_CONTEXT_DIM.min(d);
        Schema {
            context_features: vec![
                FeatureConfig::new("user_id", d_mid),
                FeatureConfig::new("user_city", 1),
                FeatureConfig::new("user_segment", 1),
            ],
            token_features: vec![
                FeatureConfig::new("item_id", d),
                FeatureConfig::new("cate_id", d_mid),
                FeatureConfig::new("action_type", 1),
                FeatureConfig::new("hour_of_day", 1),
                FeatureConfig::new("exp_item_id", d).shared("item_id"),
            ],
        }
    }

    /// Schema preset names accepted by `--schema`.
    pub fn preset_names() -> &'static [&'static str] {
        &["meituan", "meituan-mixed", "meituan-tiered"]
    }

    /// Whether `name` is a known preset (CLI validation without needing
    /// the model dim).
    pub fn is_preset(name: &str) -> bool {
        Self::preset_names().contains(&name)
    }

    /// Resolve a preset by name at the model's embedding dim.
    pub fn by_name(name: &str, emb_dim: usize) -> anyhow::Result<Schema> {
        match name {
            "meituan" => Ok(Schema::meituan_like(emb_dim, 1)),
            "meituan-mixed" => Ok(Schema::meituan_mixed(emb_dim)),
            "meituan-tiered" => Ok(Schema::meituan_tiered(emb_dim)),
            other => anyhow::bail!(
                "unknown schema preset `{other}` (expected one of {:?})",
                Self::preset_names()
            ),
        }
    }

    /// The widest feature dim — must not exceed the model dim (narrower
    /// features zero-extend into the token embedding).
    pub fn max_dim(&self) -> usize {
        self.all_features().iter().map(|f| f.dim).max().unwrap_or(0)
    }

    /// All features, context first (the order used by merged lookups).
    pub fn all_features(&self) -> Vec<FeatureConfig> {
        let mut v = self.context_features.clone();
        v.extend(self.token_features.clone());
        v
    }

    pub fn num_token_features(&self) -> usize {
        self.token_features.len()
    }

    pub fn num_context_features(&self) -> usize {
        self.context_features.len()
    }
}

/// One user sequence sample.
#[derive(Clone, Debug, PartialEq)]
pub struct Sequence {
    pub user_id: u64,
    /// Context feature values, aligned with `schema.context_features`.
    pub context: Vec<FeatureId>,
    /// Token-major feature values: `tokens[t]` aligned with
    /// `schema.token_features`.
    pub tokens: Vec<Vec<FeatureId>>,
    /// Per-sequence labels: [ctr, ctcvr] ∈ {0,1}.
    pub labels: [f32; 2],
}

impl Sequence {
    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    /// All feature ids of this sequence in (feature, occurrence) order:
    /// context first, then token features column-major per token.
    /// Returns (feature_name_index_into_all_features, id) pairs.
    pub fn flat_ids(&self, schema: &Schema) -> Vec<(usize, FeatureId)> {
        let mut out = Vec::with_capacity(
            self.context.len() + self.tokens.len() * schema.num_token_features(),
        );
        for (f, &id) in self.context.iter().enumerate() {
            out.push((f, id));
        }
        let base = schema.num_context_features();
        for tok in &self.tokens {
            for (f, &id) in tok.iter().enumerate() {
                out.push((base + f, id));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_schema_shape() {
        let s = Schema::meituan_like(16, 1);
        assert_eq!(s.num_context_features(), 3);
        assert_eq!(s.num_token_features(), 4);
        assert_eq!(s.all_features().len(), 7);
        for f in s.all_features() {
            assert_eq!(f.dim, 16);
        }
    }

    #[test]
    fn dim_factor_scales_dims() {
        let s = Schema::meituan_like(16, 8);
        for f in s.all_features() {
            assert_eq!(f.dim, 128);
        }
    }

    #[test]
    fn mixed_schema_has_two_merge_groups() {
        use crate::embedding::merge::MergePlan;
        let s = Schema::meituan_mixed(32);
        assert_eq!(s.num_context_features(), 3);
        assert_eq!(s.num_token_features(), 5);
        for f in &s.context_features {
            assert_eq!(f.dim, MIXED_CONTEXT_DIM);
        }
        for f in &s.token_features {
            assert_eq!(f.dim, 32);
        }
        assert_eq!(s.max_dim(), 32);
        let plan = MergePlan::build(&s.all_features());
        // 7 logical tables (exp_item aliases item), 2 dim groups.
        assert_eq!(plan.ops_before, 7);
        assert_eq!(plan.ops_after, 2);
        // The alias pair lands on the same (group, table).
        assert_eq!(
            plan.feature_to_table["item_id"],
            plan.feature_to_table["exp_item_id"]
        );
    }

    #[test]
    fn tiered_schema_has_three_merge_groups() {
        use crate::embedding::merge::MergePlan;
        let s = Schema::meituan_tiered(32);
        assert_eq!(s.num_context_features(), 3);
        assert_eq!(s.num_token_features(), 5);
        assert_eq!(s.max_dim(), 32);
        let dims: std::collections::BTreeSet<usize> =
            s.all_features().iter().map(|f| f.dim).collect();
        assert_eq!(dims.into_iter().collect::<Vec<_>>(), vec![1, 8, 32]);
        let plan = MergePlan::build(&s.all_features());
        // 7 logical tables (exp_item aliases item), 3 dim tiers.
        assert_eq!(plan.ops_before, 7);
        assert_eq!(plan.ops_after, 3);
        assert_eq!(
            plan.feature_to_table["item_id"],
            plan.feature_to_table["exp_item_id"]
        );
    }

    #[test]
    fn presets_resolve_by_name() {
        assert!(Schema::is_preset("meituan"));
        assert!(Schema::is_preset("meituan-mixed"));
        assert!(Schema::is_preset("meituan-tiered"));
        assert!(!Schema::is_preset("bogus"));
        let s = Schema::by_name("meituan", 16).unwrap();
        assert_eq!(s.all_features().len(), 7);
        let m = Schema::by_name("meituan-mixed", 16).unwrap();
        assert_eq!(m.all_features().len(), 8);
        assert!(Schema::by_name("bogus", 16).is_err());
        // Degenerate tiny dim: context dim clamps to the model dim.
        let t = Schema::meituan_mixed(4);
        assert!(t.all_features().iter().all(|f| f.dim <= 4));
    }

    #[test]
    fn flat_ids_layout() {
        let schema = Schema::meituan_like(8, 1);
        let seq = Sequence {
            user_id: 1,
            context: vec![10, 20, 30],
            tokens: vec![vec![1, 2, 3, 4], vec![5, 6, 7, 8]],
            labels: [1.0, 0.0],
        };
        let flat = seq.flat_ids(&schema);
        assert_eq!(flat.len(), 3 + 2 * 4);
        assert_eq!(flat[0], (0, 10));
        assert_eq!(flat[3], (3, 1)); // first token feature
        assert_eq!(flat[10], (6, 8)); // last token, last feature
    }
}
