//! Whole-step training throughput on the reference engine: steps/s and
//! tokens/s at `--threads {1,2,4}` (PR 3's tentpole — the global worker
//! pool, the batch-chunked dense forward/backward and cross-step
//! pipelining turn per-kernel speedups into end-to-end step-time
//! speedups).
//!
//! Correctness is asserted, not assumed: per-step losses and the final
//! `embedding_checksum` must be **bit-identical** across every thread
//! count and across cross-step overlap on/off; only wall-clock may
//! differ.
//!
//! CLI (after `--`): `--steps N` (default 30), `--world N` (default 1),
//! `--target-tokens N` (default 4096), `--model NAME` (default small),
//! `--threads-max N` (default 4; sweeps {1,2,4,...} up to it).

use std::time::Instant;

use mtgrboost::data::generator::GeneratorConfig;
use mtgrboost::runtime::Engine;
use mtgrboost::train::{TrainReport, Trainer, TrainerOptions};
use mtgrboost::util::bench::{ratio, BenchReport, Table};
use mtgrboost::util::cli::Args;

struct Bench {
    model: String,
    world: usize,
    steps: usize,
    target_tokens: usize,
}

impl Bench {
    fn run(&self, threads: usize, cross_step: bool) -> (TrainReport, f64) {
        let mut o = TrainerOptions::new(&self.model, self.world, self.steps);
        o.generator = GeneratorConfig {
            len_mu: 3.4,
            len_sigma: 0.6,
            min_len: 4,
            max_len: 240,
            num_users: 2_000,
            num_items: 20_000,
            ..Default::default()
        };
        o.train.target_tokens = self.target_tokens;
        o.collect_gauc = false;
        o.overlap = true;
        o.cross_step = cross_step;
        o.threads = threads;
        o.shard_capacity = 1 << 14;
        let engine = Engine::reference(7).unwrap();
        let t0 = Instant::now();
        let report = Trainer::new(o, engine).unwrap().run().unwrap();
        (report, t0.elapsed().as_secs_f64())
    }
}

/// Bit-level fingerprint of everything numerically meaningful.
fn fingerprint(r: &TrainReport) -> (Vec<(u64, u64, u64)>, u64) {
    (
        r.steps
            .iter()
            .map(|s| (s.loss_ctr.to_bits(), s.loss_ctcvr.to_bits(), s.samples))
            .collect(),
        r.embedding_checksum,
    )
}

fn main() {
    // `cargo bench` passes a bare `--bench` to harness-false binaries;
    // declare it a value-less flag so it cannot swallow `--steps`.
    let args = Args::from_env(&["bench"]);
    let bench = Bench {
        model: args.get_or("model", "small"),
        world: args.get_usize("world", 1),
        steps: args.get_usize("steps", 30),
        target_tokens: args.get_usize("target-tokens", 4096),
    };
    let threads_max = args.get_usize("threads-max", 4);
    let mut thread_counts = vec![1usize];
    let mut t = 2;
    while t <= threads_max {
        thread_counts.push(t);
        t *= 2;
    }
    // The widest pool actually swept (== threads_max only when it is a
    // power of two); the speedup metric and ablation run at this count.
    let top = *thread_counts.last().unwrap();

    let mut rep = BenchReport::new("bench_train_throughput");
    rep.add_metric("model", bench.model.as_str().into());
    rep.add_metric("world", bench.world.into());
    rep.add_metric("steps", bench.steps.into());
    let mut tbl = Table::new(
        &format!(
            "Whole-step training throughput ({} × world {}, {} steps, target {} tokens)",
            bench.model, bench.world, bench.steps, bench.target_tokens
        ),
        &["threads", "steps/s", "tokens/s", "vs 1t"],
    );

    let mut base_steps_per_s = 0.0f64;
    let mut base_fp = None;
    let mut speedup_max = 0.0f64;
    for &threads in &thread_counts {
        let (report, secs) = bench.run(threads, true);
        let fp = fingerprint(&report);
        if let Some(reference) = &base_fp {
            assert_eq!(
                &fp, reference,
                "--threads {threads} diverged from the 1-thread run"
            );
        }
        if base_fp.is_none() {
            base_fp = Some(fp);
        }
        let steps_per_s = bench.steps as f64 / secs;
        let tokens_per_s = report.wall.tokens_per_sec();
        if threads == 1 {
            base_steps_per_s = steps_per_s;
        }
        let speed = steps_per_s / base_steps_per_s;
        if threads == top {
            speedup_max = speed;
            assert!(
                report.mean_hidden_boundary_s() > 0.0,
                "cross-step pipelining must report boundary-hidden time"
            );
        }
        rep.add_metric(&format!("steps_per_s_{threads}t"), steps_per_s.into());
        rep.add_metric(&format!("tokens_per_s_{threads}t"), tokens_per_s.into());
        tbl.row(&[
            format!("{threads}"),
            format!("{steps_per_s:.2}"),
            format!("{tokens_per_s:.0}"),
            ratio(steps_per_s, base_steps_per_s),
        ]);
    }

    // Cross-step ablation at the widest pool: bit-identical numerics,
    // only the schedule differs.
    let (no_cross, secs_off) = bench.run(top, false);
    assert_eq!(
        &fingerprint(&no_cross),
        base_fp.as_ref().unwrap(),
        "cross-step off diverged from cross-step on"
    );
    assert_eq!(
        no_cross.mean_hidden_boundary_s(),
        0.0,
        "no boundary hiding without cross-step"
    );
    let steps_per_s_off = bench.steps as f64 / secs_off;
    rep.add_metric(
        &format!("steps_per_s_{top}t_cross_off"),
        steps_per_s_off.into(),
    );
    tbl.row(&[
        format!("{top} (cross off)"),
        format!("{steps_per_s_off:.2}"),
        format!("{:.0}", no_cross.wall.tokens_per_sec()),
        ratio(steps_per_s_off, base_steps_per_s),
    ]);

    rep.add_metric(&format!("speedup_{top}t_vs_1t"), speedup_max.into());
    rep.add_table(tbl);
    rep.save().unwrap();
    println!(
        "\nOne global pool fair-shared across workers, batch-chunked dense \
         compute and cross-step pipelining: whole-step wall-clock should \
         scale with --threads while losses and the embedding checksum stay \
         bit-identical."
    );
}
