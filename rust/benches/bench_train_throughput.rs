//! Whole-step training throughput on the reference engine: steps/s and
//! tokens/s over the raw-speed grid — `--threads {1, top}` ×
//! `--overlap {on,off}` × `--cross-step {on,off}` × schema
//! `{meituan, meituan-mixed}` — plus the multiplexed-exchange ablation
//! (one packed message per comm lane vs one exchange per merge group)
//! at the widest pool on the two-group schema.
//!
//! Correctness is asserted, not assumed: per-step losses and the final
//! `embedding_checksum` must be **bit-identical** across every grid
//! point of a schema, and the multiplexed exchange must move exactly
//! the same payload bytes per lane as the per-group schedule; only
//! wall-clock may differ.
//!
//! CLI (after `--`): `--steps N` (default 30), `--world N` (default 1),
//! `--target-tokens N` (default 4096), `--model NAME` (default small),
//! `--threads-max N` (default 4; the grid's top pool size).

use std::time::Instant;

use mtgrboost::data::generator::GeneratorConfig;
use mtgrboost::runtime::Engine;
use mtgrboost::train::{TrainReport, Trainer, TrainerOptions};
use mtgrboost::util::bench::{ratio, BenchReport, Table};
use mtgrboost::util::cli::Args;

struct Bench {
    model: String,
    world: usize,
    steps: usize,
    target_tokens: usize,
}

#[derive(Clone, Copy)]
struct Point {
    threads: usize,
    overlap: bool,
    cross_step: bool,
    multiplex: bool,
}

impl Bench {
    fn run(&self, schema: &str, world: usize, p: Point) -> (TrainReport, f64) {
        let mut o = TrainerOptions::new(&self.model, world, self.steps);
        o.generator = GeneratorConfig {
            len_mu: 3.4,
            len_sigma: 0.6,
            min_len: 4,
            max_len: 240,
            num_users: 2_000,
            num_items: 20_000,
            ..Default::default()
        };
        o.schema = schema.to_string();
        o.train.target_tokens = self.target_tokens;
        o.collect_gauc = false;
        o.overlap = p.overlap;
        o.cross_step = p.cross_step;
        o.multiplex_exchange = p.multiplex;
        o.threads = p.threads;
        o.shard_capacity = 1 << 14;
        let engine = Engine::reference(7).unwrap();
        let t0 = Instant::now();
        let report = Trainer::new(o, engine).unwrap().run().unwrap();
        (report, t0.elapsed().as_secs_f64())
    }
}

/// Bit-level fingerprint of everything numerically meaningful.
fn fingerprint(r: &TrainReport) -> (Vec<(u64, u64, u64)>, u64) {
    (
        r.steps
            .iter()
            .map(|s| (s.loss_ctr.to_bits(), s.loss_ctcvr.to_bits(), s.samples))
            .collect(),
        r.embedding_checksum,
    )
}

fn main() {
    // `cargo bench` passes a bare `--bench` to harness-false binaries;
    // declare it a value-less flag so it cannot swallow `--steps`.
    let args = Args::from_env(&["bench"]);
    let bench = Bench {
        model: args.get_or("model", "small"),
        world: args.get_usize("world", 1),
        steps: args.get_usize("steps", 30),
        target_tokens: args.get_usize("target-tokens", 4096),
    };
    let top = args.get_usize("threads-max", 4).max(2);

    let mut rep = BenchReport::new("bench_train_throughput");
    rep.add_metric("model", bench.model.as_str().into());
    rep.add_metric("world", bench.world.into());
    rep.add_metric("steps", bench.steps.into());
    let mut tbl = Table::new(
        &format!(
            "Whole-step training throughput ({} × world {}, {} steps, target {} tokens)",
            bench.model, bench.world, bench.steps, bench.target_tokens
        ),
        &["schema", "threads", "overlap", "cross", "steps/s", "tokens/s", "vs base"],
    );

    // ---- the raw-speed grid, per schema ------------------------------
    // Every point of a schema must agree bit for bit with the first
    // (threads=1, overlap off); only wall-clock may differ.
    for schema in ["meituan", "meituan-mixed"] {
        let tag = schema.replace('-', "_");
        let mut base_fp = None;
        let mut base_steps_per_s = 0.0f64;
        let mut top_pipelined = 0.0f64;
        for threads in [1usize, top] {
            // Cross-step without overlap is ignored by the trainer, so
            // the grid runs the three distinct flag combinations.
            for (overlap, cross_step) in [(false, false), (true, false), (true, true)] {
                let p = Point {
                    threads,
                    overlap,
                    cross_step,
                    multiplex: true,
                };
                let (report, secs) = bench.run(schema, bench.world, p);
                let fp = fingerprint(&report);
                match &base_fp {
                    None => base_fp = Some(fp),
                    Some(reference) => assert_eq!(
                        &fp, reference,
                        "{schema}: threads={threads} overlap={overlap} \
                         cross={cross_step} diverged from the base point"
                    ),
                }
                let steps_per_s = bench.steps as f64 / secs;
                if threads == 1 && !overlap {
                    base_steps_per_s = steps_per_s;
                }
                if threads == top && overlap && cross_step {
                    top_pipelined = steps_per_s;
                    assert!(
                        report.mean_hidden_boundary_s() > 0.0,
                        "cross-step pipelining must report boundary-hidden time"
                    );
                    assert!(
                        report.mean_hidden_boundary_grad_s() > 0.0,
                        "the cross-step gradient lane must report hidden time"
                    );
                }
                rep.add_metric(
                    &format!(
                        "steps_per_s_{tag}_{threads}t_ov{}_cs{}",
                        overlap as u8, cross_step as u8
                    ),
                    steps_per_s.into(),
                );
                tbl.row(&[
                    schema.into(),
                    format!("{threads}"),
                    format!("{}", overlap as u8),
                    format!("{}", cross_step as u8),
                    format!("{steps_per_s:.2}"),
                    format!("{:.0}", report.wall.tokens_per_sec()),
                    ratio(steps_per_s, base_steps_per_s),
                ]);
            }
        }
        rep.add_metric(
            &format!("speedup_{tag}_{top}t_vs_1t"),
            (top_pipelined / base_steps_per_s).into(),
        );
    }

    // ---- multiplexed-exchange ablation -------------------------------
    // Two merge groups (meituan-mixed) at world ≥ 2, widest pool, fully
    // pipelined: the packed path (one message per lane) vs one exchange
    // per group. Identical numbers, identical per-lane payload bytes —
    // the packing may only add its metered section headers.
    {
        let world = bench.world.max(2);
        let full = |multiplex| Point {
            threads: top,
            overlap: true,
            cross_step: true,
            multiplex,
        };
        let (muxed, secs_mux) = bench.run("meituan-mixed", world, full(true));
        let (plain, secs_plain) = bench.run("meituan-mixed", world, full(false));
        assert_eq!(
            fingerprint(&muxed),
            fingerprint(&plain),
            "multiplexing changed arithmetic"
        );
        for lane in 1..5 {
            assert_eq!(
                muxed.wire_payload_bytes[lane], plain.wire_payload_bytes[lane],
                "lane {lane}: packed exchange moved different payload"
            );
            assert!(
                muxed.wire_payload_bytes[lane] > 0,
                "lane {lane} must carry exchange traffic at world {world}"
            );
        }
        assert!(muxed.wire_header_bytes > 0, "packed headers must be metered");
        assert_eq!(plain.wire_header_bytes, 0, "per-group path has no headers");
        let mux_sps = bench.steps as f64 / secs_mux;
        let plain_sps = bench.steps as f64 / secs_plain;
        rep.add_metric(&format!("steps_per_s_mixed_{top}t_mux"), mux_sps.into());
        rep.add_metric(
            &format!("steps_per_s_mixed_{top}t_per_group"),
            plain_sps.into(),
        );
        rep.add_metric(
            &format!("mux_speedup_mixed_{top}t"),
            (mux_sps / plain_sps).into(),
        );
        rep.add_metric(
            "mux_header_bytes",
            (muxed.wire_header_bytes as f64).into(),
        );
        tbl.row(&[
            "meituan-mixed".into(),
            format!("{top} (mux)"),
            "1".into(),
            "1".into(),
            format!("{mux_sps:.2}"),
            format!("{:.0}", muxed.wall.tokens_per_sec()),
            ratio(mux_sps, plain_sps),
        ]);
        tbl.row(&[
            "meituan-mixed".into(),
            format!("{top} (per-group)"),
            "1".into(),
            "1".into(),
            format!("{plain_sps:.2}"),
            format!("{:.0}", plain.wall.tokens_per_sec()),
            "1.00x".into(),
        ]);
    }

    rep.add_table(tbl);
    rep.save().unwrap();
    println!(
        "\nOne global pool fair-shared across workers, batch-chunked dense \
         compute, cross-step pipelining in both directions and one packed \
         message per comm lane: whole-step wall-clock should improve down \
         the grid while losses and the embedding checksum stay bit-identical."
    );
}
