//! Figure 15: per-step min/max total token counts across 8 GPUs,
//! original (fixed-size) batching vs dynamic sequence batching.
//!
//! Paper: raw batching shows wide boxes (spreads of tens of thousands of
//! tokens); dynamic batching stabilizes every device at ≈ 76 000 tokens.

use mtgrboost::config::ModelConfig;
use mtgrboost::sim::{simulate, token_summary, SimOptions};
use mtgrboost::util::bench::{BenchReport, Table};

fn main() {
    // Match the paper's operating point: ~600-token average sequences,
    // 128 sequences per device → target ≈ 76 800 tokens.
    let batch = 128usize;
    let target = 600 * batch;

    let mut rep = BenchReport::new("fig15_token_variance");
    let mut table = Table::new(
        "Fig 15: token counts per device per step (8 GPUs, GRM 4G-1D)",
        &["batching", "mean", "std", "min", "max", "p99"],
    );
    for balanced in [false, true] {
        let mut opts = SimOptions::new(ModelConfig::grm_4g(), 8);
        opts.steps = 50;
        opts.sequence_balancing = balanced;
        opts.fixed_batch = batch;
        opts.target_tokens = target;
        let r = simulate(&opts);
        let s = token_summary(&r);
        table.row(&[
            if balanced { "dynamic (Alg. 1)" } else { "original" }.into(),
            format!("{:.0}", s.mean),
            format!("{:.0}", s.std),
            format!("{:.0}", s.min),
            format!("{:.0}", s.max),
            format!("{:.0}", s.p99),
        ]);
        rep.add_metric(
            if balanced { "balanced_std" } else { "raw_std" },
            s.std.into(),
        );
        if balanced {
            rep.add_metric("balanced_mean", s.mean.into());
        }
    }
    rep.add_table(table);
    rep.add_metric("paper_stable_tokens", (76_000usize).into());
    rep.save().unwrap();
    println!(
        "\nPaper: dynamic batching stabilizes ≈76k tokens/device; raw batching \
         spreads by tens of thousands."
    );
}
