//! Automatic table merging (§4.2) on the REAL trainer: run the
//! homogeneous schema (`meituan`, 7 logical tables → 1 merge group) and
//! the heterogeneous schema (`meituan-mixed`, 7 logical tables over two
//! dims + a `shared_table` alias → 2 merge groups) and emit the
//! **merged-vs-unmerged lookup-op counts** and **per-group dedup
//! ratios** as JSON — the paper's "fused lookups" claim as a measured
//! quantity.
//!
//! Correctness is asserted, not assumed: the merged op count must be
//! strictly below the unmerged count for both schemas, and the mixed
//! run's losses + per-group embedding checksums must be bit-identical
//! across `--threads {1,2}`.
//!
//! CLI (after `--`): `--steps N` (default 6), `--world N` (default 2),
//! `--target-tokens N` (default 1400).

use mtgrboost::data::generator::GeneratorConfig;
use mtgrboost::runtime::Engine;
use mtgrboost::train::{TrainReport, Trainer, TrainerOptions};
use mtgrboost::util::bench::{ratio, BenchReport, Table};
use mtgrboost::util::cli::Args;

fn run(schema: &str, threads: usize, world: usize, steps: usize, tokens: usize) -> TrainReport {
    let mut o = TrainerOptions::new("tiny", world, steps);
    o.schema = schema.to_string();
    o.generator = GeneratorConfig {
        len_mu: 2.8,
        len_sigma: 0.6,
        min_len: 2,
        max_len: 60,
        num_users: 800,
        num_items: 500,
        ..Default::default()
    };
    o.train.target_tokens = tokens;
    o.collect_gauc = false;
    o.threads = threads;
    o.shard_capacity = 2048;
    let engine = Engine::reference(7).unwrap();
    Trainer::new(o, engine).unwrap().run().unwrap()
}

fn fingerprint(r: &TrainReport) -> (Vec<(u64, u64)>, Vec<u64>) {
    (
        r.steps
            .iter()
            .map(|s| (s.loss_ctr.to_bits(), s.loss_ctcvr.to_bits()))
            .collect(),
        r.group_checksums.clone(),
    )
}

fn main() {
    let args = Args::from_env(&["bench"]);
    let steps = args.get_usize("steps", 6);
    let world = args.get_usize("world", 2);
    let tokens = args.get_usize("target-tokens", 1400);

    let mut rep = BenchReport::new("table_merge");
    rep.add_metric("steps", steps.into());
    rep.add_metric("world", world.into());
    let mut ops_tbl = Table::new(
        "Table merging: fused lookup operators (tiny, real trainer)",
        &["schema", "groups", "merged ops", "unmerged ops", "fusion"],
    );
    let mut grp_tbl = Table::new(
        "Per-group dedup ratios (ids raw/sent · lookups raw/done)",
        &["schema", "group", "dim", "rows", "id dedup", "lookup dedup"],
    );

    for schema in ["meituan", "meituan-mixed"] {
        let r = run(schema, 1, world, steps, tokens);
        assert!(
            r.lookup_ops_merged < r.lookup_ops_unmerged,
            "{schema}: merged ops must be strictly below unmerged \
             ({} vs {})",
            r.lookup_ops_merged,
            r.lookup_ops_unmerged
        );
        let expected_groups = if schema == "meituan" { 1 } else { 2 };
        assert_eq!(r.group_dims.len(), expected_groups, "{schema}");
        ops_tbl.row(&[
            schema.to_string(),
            r.group_dims.len().to_string(),
            r.lookup_ops_merged.to_string(),
            r.lookup_ops_unmerged.to_string(),
            ratio(r.lookup_ops_unmerged as f64, r.lookup_ops_merged as f64),
        ]);
        rep.add_metric(
            &format!("lookup_ops_merged_{schema}"),
            (r.lookup_ops_merged as f64).into(),
        );
        rep.add_metric(
            &format!("lookup_ops_unmerged_{schema}"),
            (r.lookup_ops_unmerged as f64).into(),
        );
        for (g, v) in r.group_volumes.iter().enumerate() {
            let id_ratio = v.ids_raw as f64 / v.ids_sent.max(1) as f64;
            let lk_ratio = v.lookups_raw as f64 / v.lookups_done.max(1) as f64;
            assert!(
                v.ids_sent <= v.ids_raw && v.lookups_done <= v.lookups_raw,
                "{schema} group {g}: dedup cannot amplify volume"
            );
            grp_tbl.row(&[
                schema.to_string(),
                g.to_string(),
                format!("{}D", r.group_dims[g]),
                r.group_rows[g].to_string(),
                format!("{id_ratio:.2}x"),
                format!("{lk_ratio:.2}x"),
            ]);
            rep.add_metric(
                &format!("id_dedup_ratio_{schema}_g{g}"),
                id_ratio.into(),
            );
            rep.add_metric(
                &format!("lookup_dedup_ratio_{schema}_g{g}"),
                lk_ratio.into(),
            );
        }

        // Thread bit-identity of the per-group path (losses AND
        // per-group checksums).
        let r2 = run(schema, 2, world, steps, tokens);
        assert_eq!(
            fingerprint(&r),
            fingerprint(&r2),
            "{schema}: --threads 2 diverged from --threads 1"
        );
    }

    rep.add_table(ops_tbl);
    rep.add_table(grp_tbl);
    rep.save().unwrap();
    println!(
        "\nAutomatic table merging fuses one lookup op per merge group; the \
         mixed schema exercises two physical widths end-to-end with \
         bit-identical numerics across thread counts."
    );
}
