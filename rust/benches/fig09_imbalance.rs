//! Figure 9: computational load imbalance without sequence balancing.
//!
//! Paper: training on 8 GPUs with fixed-size batches, steps 0–20 show
//! max-vs-min GPU compute times diverging, with idle time up to 25.8 ms
//! per step and per-step token spreads up to 40 000.

use mtgrboost::config::ModelConfig;
use mtgrboost::sim::{simulate, SimOptions};
use mtgrboost::util::bench::{BenchReport, Table};
use mtgrboost::util::json::Json;

fn main() {
    let mut opts = SimOptions::new(ModelConfig::grm_4g(), 8);
    opts.sequence_balancing = false;
    opts.fixed_batch = 128; // paper-scale batches (~600 tokens avg each)
    opts.steps = 21;

    let r = simulate(&opts);
    let mut table = Table::new(
        "Fig 9: per-step GPU compute time spread (8 GPUs, fixed batches, GRM-4G)",
        &["step", "min ms", "max ms", "idle ms", "token spread"],
    );
    let mut max_idle: f64 = 0.0;
    let mut max_spread = 0u64;
    for (i, s) in r.steps.iter().enumerate() {
        let busy: Vec<f64> = s
            .devices
            .iter()
            .map(|d| d.compute_s + d.lookup_s + d.comm_s)
            .collect();
        let min = busy.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = busy.iter().cloned().fold(0.0, f64::max);
        let toks: Vec<u64> = s.devices.iter().map(|d| d.tokens as u64).collect();
        let spread = toks.iter().max().unwrap() - toks.iter().min().unwrap();
        max_idle = max_idle.max((max - min) * 1e3);
        max_spread = max_spread.max(spread);
        table.row(&[
            i.to_string(),
            format!("{:.1}", min * 1e3),
            format!("{:.1}", max * 1e3),
            format!("{:.1}", (max - min) * 1e3),
            spread.to_string(),
        ]);
    }
    let mut rep = BenchReport::new("fig09_imbalance");
    rep.add_table(table);
    rep.add_metric("max_idle_ms", max_idle.into());
    rep.add_metric("max_token_spread", max_spread.into());
    rep.add_metric("paper_max_idle_ms", 25.8.into());
    rep.add_metric("paper_max_token_spread", Json::from(40_000usize));
    rep.save().unwrap();
    println!(
        "\nmax idle {max_idle:.1} ms (paper: up to 25.8), max token spread \
         {max_spread} (paper: up to 40k)"
    );
}
