//! Table 2: batch sizes and average GPU memory utilization with
//! sequence balancing disabled vs enabled.
//!
//! Paper: GRM 4G-1D 480 → 496 batch, 86.3% → 95.7% memory utilization;
//! GRM 110G-1D 80 → 116 batch, 75.3% → 90.3%.
//!
//! Mechanism reproduced: with fixed batching the activation memory must
//! be *provisioned for the worst batch* (long-sequence clusters) while
//! the *average* batch uses much less — the provisioned-but-idle gap is
//! wasted memory. Dynamic batching caps every step near the token
//! target, so average ≈ peak and the same device can also run a larger
//! average batch. We measure the average/peak token statistics with the
//! real batchers and convert the headroom into utilization points
//! (embedding tables + params anchor the static share of memory).

use mtgrboost::config::ModelConfig;
use mtgrboost::sim::{simulate, SimOptions};
use mtgrboost::util::bench::{BenchReport, Table};

const A100: f64 = 80.0e9;

fn main() {
    let mut rep = BenchReport::new("table2_memory_util");
    let mut table = Table::new(
        "Table 2: batch size & memory utilization, balancing off -> on",
        &["model", "batch off", "batch on (avg)", "mem off", "mem on"],
    );
    for (label, model, fixed_batch) in [
        ("GRM 4G 1D", ModelConfig::grm_4g(), 480usize),
        ("GRM 110G 1D", ModelConfig::grm_110g(), 80usize),
    ] {
        // Bytes of live activations per token (fwd+bwd working set,
        // ~40 B per hidden unit per block incl. the 4d UQKV tensors).
        let bpt = (model.emb_dim * model.hstu_blocks) as f64 * 40.0;

        // Fixed mode: measure average and worst per-device token counts.
        let mut off = SimOptions::new(model.clone(), 8);
        off.steps = 60;
        off.sequence_balancing = false;
        off.fixed_batch = fixed_batch;
        let r_off = simulate(&off);
        let toks: Vec<f64> = r_off
            .steps
            .iter()
            .flat_map(|s| s.devices.iter().map(|d| d.tokens as f64))
            .collect();
        let avg_t = toks.iter().sum::<f64>() / toks.len() as f64;
        let peak_t = toks.iter().cloned().fold(0.0, f64::max) * 1.10; // safety margin

        // The device must provision peak_t×bpt activations; static
        // memory (tables + optimizer + params) fills the rest of the
        // device. Anchor: provisioning targets a full device.
        let static_bytes = A100 - peak_t * bpt;
        let util_off = (static_bytes + avg_t * bpt) / A100;

        // Dynamic mode: the token target can safely rise to consume the
        // former worst-case headroom; average ≈ peak ≈ target.
        let target = peak_t * 0.98;
        let mut on = off.clone();
        on.sequence_balancing = true;
        on.target_tokens = target as usize;
        let r_on = simulate(&on);
        let batch_on: f64 = r_on
            .steps
            .iter()
            .flat_map(|s| s.devices.iter().map(|d| d.sequences as f64))
            .sum::<f64>()
            / (r_on.steps.len() * 8) as f64;
        let on_toks: Vec<f64> = r_on
            .steps
            .iter()
            .flat_map(|s| s.devices.iter().map(|d| d.tokens as f64))
            .collect();
        let avg_on = on_toks.iter().sum::<f64>() / on_toks.len() as f64;
        let util_on = (static_bytes + avg_on * bpt) / A100;

        table.row(&[
            label.into(),
            fixed_batch.to_string(),
            format!("{batch_on:.0}"),
            format!("{:.1}%", util_off * 100.0),
            format!("{:.1}%", util_on * 100.0),
        ]);
        rep.add_metric(
            &format!("util_gain_pts_{}", label.replace(' ', "_")),
            ((util_on - util_off) * 100.0).into(),
        );
        rep.add_metric(
            &format!("batch_on_{}", label.replace(' ', "_")),
            batch_on.into(),
        );
    }
    rep.add_table(table);
    rep.add_metric("paper_4g", "480->496 @ 86.3->95.7%".into());
    rep.add_metric("paper_110g", "80->116 @ 75.3->90.3%".into());

    // Real-table memory-pressure probe: a ConcurrentDynamicTable under
    // a hard row budget (the situation Table 2's utilization numbers
    // are ultimately about). Overlapping skewed ids overflow the
    // budget; the table's own counters — evictions, expansions, worst
    // stripe load — land in the JSON artifact so memory-pressure
    // behaviour is observable run over run.
    {
        use mtgrboost::embedding::concurrent::ConcurrentDynamicTable;
        use mtgrboost::embedding::dynamic_table::DynamicTableConfig;
        let probe = ConcurrentDynamicTable::new(
            DynamicTableConfig::new(16)
                .with_capacity(4096)
                .with_seed(11)
                .with_max_rows(2048),
            8,
        );
        let mut buf = vec![0.0f32; 16];
        // 20k distinct ids against a 2048-row budget (~10× overflow),
        // with the head revisited so LRU has hot rows to keep.
        for id in 0..20_000u64 {
            probe.lookup_or_insert(id, &mut buf);
            probe.lookup_or_insert(id % 64, &mut buf);
        }
        let st = probe.stats();
        assert!(st.evictions > 0, "row budget must force evictions");
        rep.add_metric("probe_rows_resident", probe.len().into());
        rep.add_metric("probe_row_budget", 2048usize.into());
        rep.add_metric("probe_inserts", st.inserts.into());
        rep.add_metric("probe_evictions", st.evictions.into());
        rep.add_metric("probe_expansions", st.expansions.into());
        rep.add_metric("probe_max_load_factor", probe.max_load_factor().into());
    }

    // Mixed-precision storage probe: the same table family under the
    // FP32-hot / FP16-cold policy (§5.2). A skewed access pattern
    // splits the census — a revisited head crosses the post-bump hot
    // threshold while the one-shot tail stays cold on the binary16
    // grid — and the effective value bytes land in the artifact next
    // to the all-FP32 footprint they undercut.
    {
        use mtgrboost::embedding::concurrent::ConcurrentDynamicTable;
        use mtgrboost::embedding::dynamic_table::DynamicTableConfig;
        use mtgrboost::embedding::precision::PrecisionPolicy;
        const DIM: usize = 16;
        let probe = ConcurrentDynamicTable::new(
            DynamicTableConfig::new(DIM).with_capacity(8192).with_seed(11),
            8,
        )
        .with_precision(PrecisionPolicy::mixed(4));
        let mut buf = vec![0.0f32; DIM];
        for id in 0..4096u64 {
            probe.lookup_or_insert(id, &mut buf);
        }
        for _ in 0..4 {
            for id in 0..256u64 {
                probe.lookup_or_insert(id, &mut buf);
            }
        }
        let ps = probe.precision_stats();
        assert!(
            ps.hot_rows > 0 && ps.cold_rows > 0,
            "skewed traffic must split the census: {ps:?}"
        );
        let all_fp32 = probe.len() * DIM * 4;
        let effective = probe.effective_value_bytes();
        assert!(
            effective < all_fp32,
            "mixed storage must undercut all-fp32: {effective} vs {all_fp32}"
        );
        rep.add_metric("precision_hot_rows", ps.hot_rows.into());
        rep.add_metric("precision_cold_rows", ps.cold_rows.into());
        rep.add_metric("precision_quantize_ops", (ps.quantize_ops as usize).into());
        rep.add_metric("precision_effective_value_bytes", effective.into());
        rep.add_metric("precision_all_fp32_bytes", all_fp32.into());
    }
    rep.save().unwrap();
}
