//! Figure 16: two-stage ID deduplication ablation — throughput for
//! (a) no dedup, (b) comm-unique only, (c) lookup-unique only,
//! (d) two-stage — at embedding dim factors 1D and 64D, 16→64 GPUs.
//!
//! Paper: two-stage achieves 1.1×–3.7× over (a), gains amplify with GPU
//! count and embedding dimension; comm-unique beats lookup-unique
//! because embedding communication dominates.

use mtgrboost::config::ModelConfig;
use mtgrboost::embedding::dedup::DedupStrategy;
use mtgrboost::sim::{simulate, SimOptions};
use mtgrboost::util::bench::{ratio, BenchReport, Table};

fn main() {
    let strategies = [
        DedupStrategy::None,
        DedupStrategy::CommUnique,
        DedupStrategy::LookupUnique,
        DedupStrategy::TwoStage,
    ];
    let mut rep = BenchReport::new("fig16_dedup");
    let mut table = Table::new(
        "Fig 16: dedup strategies (GRM 4G, simulated seq/s)",
        &["dim", "gpus", "w/o", "comm", "lookup", "two-stage", "two-stage vs w/o"],
    );
    for dim_factor in [1usize, 64] {
        for world in [16usize, 32, 64] {
            let mut thr = Vec::new();
            for &s in &strategies {
                let mut opts = SimOptions::new(
                    ModelConfig::grm_4g().with_dim_factor(dim_factor),
                    world,
                );
                opts.steps = 25;
                opts.dedup = s;
                opts.resident_rows = 1_000_000;
                thr.push(simulate(&opts).throughput);
            }
            table.row(&[
                format!("{dim_factor}D"),
                world.to_string(),
                format!("{:.0}", thr[0]),
                format!("{:.0}", thr[1]),
                format!("{:.0}", thr[2]),
                format!("{:.0}", thr[3]),
                ratio(thr[3], thr[0]),
            ]);
            rep.add_metric(
                &format!("two_stage_gain_{dim_factor}d_{world}gpu"),
                (thr[3] / thr[0]).into(),
            );
            // The paper's ordering claim: comm-unique > lookup-unique.
            rep.add_metric(
                &format!("comm_beats_lookup_{dim_factor}d_{world}gpu"),
                (thr[1] > thr[2]).into(),
            );
        }
    }
    rep.add_table(table);
    rep.add_metric("paper_range", "1.1x - 3.7x".into());
    rep.save().unwrap();
}
