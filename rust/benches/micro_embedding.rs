//! Micro-benchmarks of the sparse-side hot paths: MurmurHash3, grouped
//! parallel probing vs linear probing, dynamic-table ops, dedup kernels,
//! gather/scatter. These feed the §Perf iteration log in EXPERIMENTS.md.

use mtgrboost::embedding::dedup::{gather_rows, scatter_accumulate, Dedup};
use mtgrboost::embedding::dynamic_table::{DynamicEmbeddingTable, DynamicTableConfig};
use mtgrboost::embedding::hash::{fmix64, hash_id, murmur3_x86_32};
use mtgrboost::embedding::EmbeddingStore;
use mtgrboost::util::bench::{bench_fn, BenchReport};
use mtgrboost::util::rng::{Xoshiro256, Zipf};

fn main() {
    let mut rep = BenchReport::new("micro_embedding");
    let mut rng = Xoshiro256::new(42);

    // ---- hashing -------------------------------------------------------
    let keys: Vec<u64> = (0..4096).map(|_| rng.next_u64()).collect();
    let r = bench_fn("fmix64_4096_keys", 10, 50, |_| {
        let mut acc = 0u64;
        for &k in &keys {
            acc ^= fmix64(k);
        }
        std::hint::black_box(acc);
    });
    rep.add_metric("fmix64_ns_per_key", (r.ns_per_iter() / 4096.0).into());
    let data: Vec<u8> = (0..256).map(|i| i as u8).collect();
    let r = bench_fn("murmur3_x86_32_256B", 10, 50, |_| {
        std::hint::black_box(murmur3_x86_32(&data, 0));
    });
    rep.add_metric("murmur3_256B_ns", r.ns_per_iter().into());

    // ---- probing: grouped-parallel vs naive linear ----------------------
    let m = 1usize << 16;
    let mask = (m - 1) as u64;
    let r = bench_fn("grouped_probe_step_4096", 10, 50, |_| {
        let mut acc = 0u64;
        for &k in &keys {
            let s = DynamicEmbeddingTable::probe_step(k, m as u64, 4);
            acc ^= (hash_id(k, 0) + s) & mask;
        }
        std::hint::black_box(acc);
    });
    rep.add_metric("grouped_probe_ns_per_key", (r.ns_per_iter() / 4096.0).into());

    // ---- table ops under Zipf churn -------------------------------------
    const DIM: usize = 64;
    let zipf = Zipf::new(100_000, 1.05);
    let ids: Vec<u64> = (0..100_000).map(|_| zipf.sample(&mut rng) as u64).collect();
    let mut table =
        DynamicEmbeddingTable::new(DynamicTableConfig::new(DIM).with_capacity(4096));
    let mut buf = vec![0.0f32; DIM];
    // Warm fill.
    for &id in &ids[..50_000] {
        table.lookup_or_insert(id, &mut buf);
    }
    let mut i = 0usize;
    let r = bench_fn("dyn_table_lookup_hit_dim64", 2, 20, |_| {
        for _ in 0..10_000 {
            table.lookup_or_insert(ids[i % 50_000], &mut buf);
            i += 1;
        }
    });
    rep.add_metric("lookup_hit_ns", (r.ns_per_iter() / 1e4).into());

    let delta = vec![0.01f32; DIM];
    i = 0;
    let r = bench_fn("dyn_table_apply_delta_dim64", 2, 20, |_| {
        for _ in 0..10_000 {
            table.apply_delta(ids[i % 50_000], &delta);
            i += 1;
        }
    });
    rep.add_metric("apply_delta_ns", (r.ns_per_iter() / 1e4).into());

    // ---- dedup kernels ---------------------------------------------------
    let batch: Vec<u64> = (0..100_000).map(|_| zipf.sample(&mut rng) as u64).collect();
    // Pin the kernels explicitly: `Dedup::of` now auto-switches at
    // DEDUP_SORT_THRESHOLD, and 100k occurrences would pick Sort.
    let r = bench_fn("dedup_hash_100k_zipf", 2, 20, |_| {
        std::hint::black_box(Dedup::of_hash(&batch));
    });
    rep.add_metric("dedup_hash_ns_per_id", (r.ns_per_iter() / 1e5).into());
    let r = bench_fn("dedup_sort_100k_zipf", 2, 20, |_| {
        std::hint::black_box(Dedup::of_sorted(&batch));
    });
    rep.add_metric("dedup_sort_ns_per_id", (r.ns_per_iter() / 1e5).into());

    let d = Dedup::of(&batch);
    let rows: Vec<f32> = (0..d.unique.len() * DIM).map(|_| rng.next_f32()).collect();
    let mut out = vec![0.0f32; batch.len() * DIM];
    let r = bench_fn("gather_rows_100k_dim64", 2, 20, |_| {
        gather_rows(&rows, DIM, &d.inverse, &mut out);
        std::hint::black_box(&out);
    });
    rep.add_metric("gather_ns_per_row", (r.ns_per_iter() / 1e5).into());

    let grads: Vec<f32> = (0..batch.len() * DIM).map(|_| rng.next_f32()).collect();
    let mut acc = vec![0.0f32; d.unique.len() * DIM];
    let r = bench_fn("scatter_accumulate_100k_dim64", 2, 20, |_| {
        scatter_accumulate(&grads, DIM, &d.inverse, &mut acc);
        std::hint::black_box(&acc);
    });
    rep.add_metric("scatter_ns_per_row", (r.ns_per_iter() / 1e5).into());

    println!(
        "\ntable: {} rows, {:.1} MB, load factor {:.2}, {} expansions",
        table.len(),
        table.memory_bytes() as f64 / 1e6,
        table.load_factor(),
        table.stats.expansions
    );
    rep.add_metric("table_probes_per_op", (table.stats.probes as f64
        / (table.stats.hits + table.stats.misses).max(1) as f64)
        .into());
    rep.save().unwrap();
}
