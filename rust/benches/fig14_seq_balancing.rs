//! Figure 14: throughput with sequence balancing disabled vs enabled,
//! scaling 8 → 64 GPUs, for GRM 4G-1D and 110G-1D.
//!
//! Paper: average gains +4.4% (4G) and +26.5% (110G); the gain grows
//! with GPU count (more devices → higher chance one draws a pathological
//! batch and stalls the synchronous step) and peaks at +33.5% for 110G
//! on 64 GPUs.

use mtgrboost::config::ModelConfig;
use mtgrboost::sim::{simulate, SimOptions};
use mtgrboost::util::bench::{pct_gain, BenchReport, Table};

fn main() {
    let mut table = Table::new(
        "Fig 14: sequence balancing gain by world size (simulated seq/s)",
        &["config", "gpus", "disabled", "enabled", "gain"],
    );
    let mut rep = BenchReport::new("fig14_seq_balancing");
    for (label, model) in [
        ("4G 1D", ModelConfig::grm_4g()),
        ("110G 1D", ModelConfig::grm_110g()),
    ] {
        let mut gains = Vec::new();
        for world in [8usize, 16, 32, 64] {
            let run = |balancing: bool| {
                let mut opts = SimOptions::new(model.clone(), world);
                opts.steps = 30;
                opts.sequence_balancing = balancing;
                simulate(&opts).throughput
            };
            let off = run(false);
            let on = run(true);
            gains.push(on / off - 1.0);
            table.row(&[
                label.into(),
                world.to_string(),
                format!("{off:.0}"),
                format!("{on:.0}"),
                pct_gain(on, off),
            ]);
        }
        let avg = gains.iter().sum::<f64>() / gains.len() as f64;
        rep.add_metric(
            &format!("avg_gain_pct_{}", label.replace(' ', "_")),
            (avg * 100.0).into(),
        );
        rep.add_metric(
            &format!("gain_at_64_pct_{}", label.replace(' ', "_")),
            (gains.last().unwrap() * 100.0).into(),
        );
    }
    rep.add_table(table);
    rep.add_metric("paper_avg_4g_pct", 4.4.into());
    rep.add_metric("paper_avg_110g_pct", 26.5.into());
    rep.add_metric("paper_peak_110g_64gpu_pct", 33.5.into());
    rep.save().unwrap();
}
