//! The crash-recovery drill matrix through the real binary: for each
//! world size, kill each rank once at an early / mid / late step and
//! assert the recovered run is **bit-identical** to an uninterrupted
//! single-process run of the same argv (final losses, per-group
//! embedding checksums, overlapping step records). One reference run
//! per world is cached and reused across the kills.
//!
//! Every drill's recovery accounting (recoveries, replayed steps,
//! heartbeat misses, transport retries) lands in the bench JSON, so CI
//! archives the fault-tolerance trajectory next to the perf benches.
//! Any bit divergence or missed recovery panics → nonzero exit.
//!
//! CLI (after `--`): `--worlds 2,4` (comma list), `--kill-steps 2,7,12`
//! (early/mid/late; the run is 15 steps = 3 intervals × 5).

use std::path::{Path, PathBuf};
use std::process::Command;
use std::time::Instant;

use mtgrboost::dist::worker::parse_hex64;
use mtgrboost::util::bench::{BenchReport, Table};
use mtgrboost::util::cli::Args;
use mtgrboost::util::json::Json;

const BIN: &str = env!("CARGO_BIN_EXE_mtgrboost");

fn tmp(tag: &str) -> PathBuf {
    // Short: Unix socket paths cap at ~108 bytes.
    let d = std::env::temp_dir().join(format!("mtgr_bdd_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn train_tail(world: usize, sync_dir: &Path) -> Vec<String> {
    [
        "--model", "tiny", "--mode", "online", "--sync-interval", "5",
        "--intervals", "3", "--seed", "977", "--threads", "1",
        "--log-every", "0", "--target-tokens", "512", "--max-len", "32",
        "--len-mu", "2.5", "--gauc", "off",
    ]
    .iter()
    .map(|s| s.to_string())
    .chain([
        "--world".to_string(),
        world.to_string(),
        "--sync-dir".to_string(),
        sync_dir.display().to_string(),
    ])
    .collect()
}

fn run_to_json(subcmd: &str, args: &[String], report: &Path) -> Json {
    let out = Command::new(BIN)
        .arg(subcmd)
        .args(args)
        .arg("--report-json")
        .arg(report)
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "{subcmd} failed ({}):\n{}",
        out.status,
        String::from_utf8_lossy(&out.stderr)
    );
    Json::parse(&std::fs::read_to_string(report).unwrap()).unwrap()
}

fn checksums(j: &Json) -> Vec<u64> {
    j.get("group_checksums")
        .as_arr()
        .unwrap()
        .iter()
        .map(|c| parse_hex64(c.as_str().unwrap()).unwrap())
        .collect()
}

fn final_bits(j: &Json) -> (u64, u64) {
    (
        parse_hex64(j.expect_str("final_loss_ctr_bits").unwrap()).unwrap(),
        parse_hex64(j.expect_str("final_loss_ctcvr_bits").unwrap()).unwrap(),
    )
}

fn step_bits(j: &Json) -> Vec<(usize, u64, u64)> {
    j.get("steps")
        .as_arr()
        .unwrap()
        .iter()
        .map(|s| {
            (
                s.expect_usize("step").unwrap(),
                parse_hex64(s.expect_str("loss_ctr_bits").unwrap()).unwrap(),
                parse_hex64(s.expect_str("loss_ctcvr_bits").unwrap()).unwrap(),
            )
        })
        .collect()
}

fn assert_bit_identical(dist: &Json, reference: &Json, drill: &str) {
    assert_eq!(final_bits(dist), final_bits(reference), "{drill}: final loss bits");
    assert_eq!(checksums(dist), checksums(reference), "{drill}: group checksums");
    let ref_steps = step_bits(reference);
    for (step, ctr, ctcvr) in step_bits(dist) {
        let r = ref_steps
            .iter()
            .find(|(s, _, _)| *s == step)
            .unwrap_or_else(|| panic!("{drill}: reference has no step {step}"));
        assert_eq!((ctr, ctcvr), (r.1, r.2), "{drill}: loss bits at step {step}");
    }
}

fn parse_list(s: &str, flag: &str) -> Vec<usize> {
    s.split(',')
        .map(|t| {
            t.trim()
                .parse()
                .unwrap_or_else(|_| panic!("--{flag} expects comma-separated integers, got `{t}`"))
        })
        .collect()
}

fn main() {
    let args = Args::from_env(&["bench"]);
    let worlds = parse_list(&args.get_or("worlds", "2,4"), "worlds");
    let kill_steps = parse_list(&args.get_or("kill-steps", "2,7,12"), "kill-steps");

    let mut rep = BenchReport::new("bench_dist_drill");
    let mut tbl = Table::new(
        "Crash-recovery drill matrix (kill rank r at step s, 3 intervals × 5 steps)",
        &["world", "rank", "kill step", "recoveries", "replayed", "hb misses", "secs", "bits"],
    );

    let mut drills = 0usize;
    let mut total_replayed = 0u64;
    for &world in &worlds {
        let ref_dir = tmp(&format!("ref{world}"));
        let sync = ref_dir.join("sync");
        std::fs::create_dir_all(&sync).unwrap();
        let reference = run_to_json("train", &train_tail(world, &sync), &ref_dir.join("r.json"));

        for rank in 0..world {
            for &step in &kill_steps {
                let drill = format!("w{world}_r{rank}_s{step}");
                let d = tmp(&drill);
                let sync = d.join("sync");
                std::fs::create_dir_all(&sync).unwrap();
                let mut dist_args = train_tail(world, &sync);
                dist_args.extend([
                    "--run-dir".to_string(),
                    d.join("run").display().to_string(),
                    "--fault".to_string(),
                    format!("kill:rank={rank},step={step}"),
                ]);
                let t0 = Instant::now();
                let dist = run_to_json("train-dist", &dist_args, &d.join("d.json"));
                let secs = t0.elapsed().as_secs_f64();

                let stats = dist.get("dist");
                let recoveries = stats.expect_usize("recoveries").unwrap();
                let replayed = stats.expect_usize("replayed_steps").unwrap();
                let misses = stats.expect_usize("heartbeat_misses").unwrap();
                assert_eq!(recoveries, 1, "{drill}: exactly one gang restart");
                assert!(replayed > 0, "{drill}: a mid-run kill must replay steps");
                assert_bit_identical(&dist, &reference, &drill);

                rep.add_metric(&format!("replayed_steps_{drill}"), replayed.into());
                tbl.row(&[
                    format!("{world}"),
                    format!("{rank}"),
                    format!("{step}"),
                    format!("{recoveries}"),
                    format!("{replayed}"),
                    format!("{misses}"),
                    format!("{secs:.2}"),
                    "identical".to_string(),
                ]);
                drills += 1;
                total_replayed += replayed as u64;
                std::fs::remove_dir_all(&d).ok();
            }
        }
        std::fs::remove_dir_all(&ref_dir).ok();
    }

    rep.add_metric("drills", drills.into());
    rep.add_metric("total_replayed_steps", (total_replayed as usize).into());
    rep.add_table(tbl);
    rep.save().unwrap();
    println!(
        "\n{drills} kill drills across worlds {worlds:?}: every recovered run \
         bit-identical to its uninterrupted single-process reference."
    );
}
