//! Steady-state online-learning throughput: steps/s of `--mode online`
//! (admission + TTL expiry + periodic delta sync) at `--threads
//! {1,2,4}`, plus the delta-sync overhead (sync on vs tracking-only).
//!
//! Correctness is asserted, not assumed: the per-step loss trace, the
//! final `embedding_checksum` and every online counter must be
//! **bit-identical** across thread counts — the online subsystem's
//! determinism contract (admission decisions are pure functions of
//! `(seed, id, count)`; sweeps and delta drains run in sorted id
//! order).
//!
//! CLI (after `--`): `--intervals N` (default 20), `--sync-interval N`
//! (default 10), `--world N` (default 1), `--target-tokens N` (default
//! 4096), `--model NAME` (default small), `--threads-max N` (default 4).

use std::path::PathBuf;
use std::time::Instant;

use mtgrboost::data::generator::GeneratorConfig;
use mtgrboost::online::{AdmissionConfig, OnlineOptions};
use mtgrboost::runtime::Engine;
use mtgrboost::train::{TrainReport, Trainer, TrainerOptions};
use mtgrboost::util::bench::{ratio, BenchReport, Table};
use mtgrboost::util::cli::Args;

struct Bench {
    model: String,
    world: usize,
    intervals: usize,
    sync_interval: usize,
    target_tokens: usize,
}

impl Bench {
    fn steps(&self) -> usize {
        self.intervals * self.sync_interval
    }

    fn run(&self, threads: usize, sync_dir: Option<PathBuf>) -> (TrainReport, f64) {
        let mut o = TrainerOptions::new(&self.model, self.world, 0);
        o.generator = GeneratorConfig {
            len_mu: 3.4,
            len_sigma: 0.6,
            min_len: 4,
            max_len: 240,
            num_users: 2_000,
            num_items: 20_000,
            new_user_rate: 0.2,
            new_item_rate: 0.2,
            ..Default::default()
        };
        o.train.target_tokens = self.target_tokens;
        o.collect_gauc = false;
        o.threads = threads;
        o.shard_capacity = 1 << 14;
        let mut online = OnlineOptions::new(self.sync_interval);
        online.intervals = self.intervals;
        online.feature_ttl = (3 * self.sync_interval) as u64;
        online.admission = Some(AdmissionConfig::new(2, 0.1));
        online.day_every = 4;
        online.sync_dir = sync_dir;
        o.online = Some(online);
        let engine = Engine::reference(7).unwrap();
        let t0 = Instant::now();
        let report = Trainer::new(o, engine).unwrap().run().unwrap();
        (report, t0.elapsed().as_secs_f64())
    }
}

/// Bit-level fingerprint: losses, checksum and the online counters.
fn fingerprint(r: &TrainReport) -> (Vec<(u64, u64, u64)>, u64, [u64; 5]) {
    (
        r.steps
            .iter()
            .map(|s| (s.loss_ctr.to_bits(), s.loss_ctcvr.to_bits(), s.samples))
            .collect(),
        r.embedding_checksum,
        [
            r.online_admitted,
            r.online_rejected,
            r.online_expired,
            r.online_synced_rows,
            r.online_sync_bytes,
        ],
    )
}

fn tmp(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "mtgr_bench_online_{tag}_{}",
        std::process::id()
    ));
    std::fs::remove_dir_all(&d).ok();
    d
}

fn main() {
    // `cargo bench` passes a bare `--bench` to harness-false binaries;
    // declare it a value-less flag so it cannot swallow `--intervals`.
    let args = Args::from_env(&["bench"]);
    let bench = Bench {
        model: args.get_or("model", "small"),
        world: args.get_usize("world", 1),
        intervals: args.get_usize("intervals", 20),
        sync_interval: args.get_usize("sync-interval", 10),
        target_tokens: args.get_usize("target-tokens", 4096),
    };
    let threads_max = args.get_usize("threads-max", 4);
    let mut thread_counts = vec![1usize];
    let mut t = 2;
    while t <= threads_max {
        thread_counts.push(t);
        t *= 2;
    }
    let top = *thread_counts.last().unwrap();

    let mut rep = BenchReport::new("bench_online_throughput");
    rep.add_metric("model", bench.model.as_str().into());
    rep.add_metric("world", bench.world.into());
    rep.add_metric("intervals", bench.intervals.into());
    rep.add_metric("sync_interval", bench.sync_interval.into());
    let mut tbl = Table::new(
        &format!(
            "Online steady-state throughput ({} × world {}, {} intervals × {} steps)",
            bench.model, bench.world, bench.intervals, bench.sync_interval
        ),
        &["threads", "steps/s", "tokens/s", "vs 1t"],
    );

    let mut base_steps_per_s = 0.0f64;
    let mut base_fp = None;
    let mut top_secs = 0.0f64;
    for &threads in &thread_counts {
        let dir = tmp(&format!("{threads}t"));
        let (report, secs) = bench.run(threads, Some(dir.clone()));
        std::fs::remove_dir_all(dir).ok();
        let fp = fingerprint(&report);
        if let Some(reference) = &base_fp {
            assert_eq!(
                &fp, reference,
                "--threads {threads} diverged from the 1-thread online run"
            );
        } else {
            // The online machinery must actually engage.
            assert!(report.online_admitted > 0, "no admissions");
            assert!(report.online_rejected > 0, "admission filtered nothing");
            assert!(report.online_expired > 0, "TTL retired nothing");
            assert!(report.online_sync_bytes > 0, "no delta volume");
            base_fp = Some(fp);
            rep.add_metric("online_admitted", report.online_admitted.into());
            rep.add_metric("online_rejected", report.online_rejected.into());
            rep.add_metric("online_expired", report.online_expired.into());
            rep.add_metric("online_synced_rows", report.online_synced_rows.into());
            rep.add_metric("online_sync_bytes", report.online_sync_bytes.into());
        }
        let steps_per_s = bench.steps() as f64 / secs;
        let tokens_per_s = report.wall.tokens_per_sec();
        if threads == 1 {
            base_steps_per_s = steps_per_s;
        }
        if threads == top {
            top_secs = secs;
        }
        rep.add_metric(&format!("steps_per_s_{threads}t"), steps_per_s.into());
        rep.add_metric(&format!("tokens_per_s_{threads}t"), tokens_per_s.into());
        tbl.row(&[
            format!("{threads}"),
            format!("{steps_per_s:.2}"),
            format!("{tokens_per_s:.0}"),
            ratio(steps_per_s, base_steps_per_s),
        ]);
    }

    // Delta-sync overhead: same run at the widest pool with tracking
    // only (no snapshot files). Numerics are identical either way —
    // only the export work differs.
    let (no_sync, secs_off) = bench.run(top, None);
    assert_eq!(
        &fingerprint(&no_sync),
        base_fp.as_ref().unwrap(),
        "sync-dir off diverged (export must not affect numerics)"
    );
    let steps_per_s_off = bench.steps() as f64 / secs_off;
    let overhead_pct = 100.0 * (secs_off.max(top_secs) - secs_off) / secs_off.max(1e-9);
    rep.add_metric(&format!("steps_per_s_{top}t_no_sync"), steps_per_s_off.into());
    rep.add_metric("sync_overhead_pct", overhead_pct.into());
    tbl.row(&[
        format!("{top} (no sync)"),
        format!("{steps_per_s_off:.2}"),
        format!("{:.0}", no_sync.wall.tokens_per_sec()),
        ratio(steps_per_s_off, base_steps_per_s),
    ]);

    rep.add_table(tbl);
    rep.save().unwrap();
    println!(
        "\nOnline mode sustains streaming training — admission keeps one-shot \
         IDs out of the table, TTL bounds residency, and the periodic delta \
         snapshot (sync_overhead_pct) is the full cost of keeping a serving \
         fleet in sync."
    );
}
