//! Mixed-precision ablation (§5.2): the FP32 baseline vs the
//! FP32-hot/FP16-cold policy on the two-group schema, fully pipelined.
//!
//! What the paper claims — and this bench asserts, not just reports:
//! cold rows stored and shipped at half width must put the reply and
//! gradient wire bytes AND the effective storage bytes strictly below
//! the FP32 baseline, while the ID lane (workload-determined, not
//! precision-determined) moves exactly the same bytes and the losses
//! stay equal to within the binary16 grid's drift. The JSON artifact
//! carries steps/s, per-lane wire bytes, the hot/cold census,
//! effective storage bytes, RSS, and quantization-error telemetry.
//!
//! CLI (after `--`): `--steps N` (default 30), `--world N` (default 2),
//! `--target-tokens N` (default 4096), `--model NAME` (default small),
//! `--threads N` (default 4), `--hot-threshold N` (default 4).

use std::time::Instant;

use mtgrboost::data::generator::GeneratorConfig;
use mtgrboost::embedding::precision::PrecisionMode;
use mtgrboost::runtime::Engine;
use mtgrboost::train::{TrainReport, Trainer, TrainerOptions};
use mtgrboost::util::bench::{pct_gain, ratio, BenchReport, Table};
use mtgrboost::util::cli::Args;
use mtgrboost::util::f16::quantize_f16;
use mtgrboost::util::rng::Xoshiro256;

/// Resident set size in bytes (Linux `/proc/self/statm`; 0 elsewhere).
fn rss_bytes() -> u64 {
    std::fs::read_to_string("/proc/self/statm")
        .ok()
        .and_then(|s| {
            s.split_whitespace()
                .nth(1)
                .and_then(|pages| pages.parse::<u64>().ok())
        })
        .map(|pages| pages * 4096)
        .unwrap_or(0)
}

fn mean_loss(r: &TrainReport) -> f64 {
    r.steps.iter().map(|s| s.loss_ctr).sum::<f64>() / r.steps.len() as f64
}

fn main() {
    // `cargo bench` passes a bare `--bench` to harness-false binaries;
    // declare it a value-less flag so it cannot swallow `--steps`.
    let args = Args::from_env(&["bench"]);
    let model = args.get_or("model", "small");
    let world = args.get_usize("world", 2);
    let steps = args.get_usize("steps", 30);
    let target_tokens = args.get_usize("target-tokens", 4096);
    let threads = args.get_usize("threads", 4);
    let hot_threshold = args.get_usize("hot-threshold", 4) as u32;

    let run = |precision: PrecisionMode| -> (TrainReport, f64, u64) {
        let mut o = TrainerOptions::new(&model, world, steps);
        o.generator = GeneratorConfig {
            len_mu: 3.4,
            len_sigma: 0.6,
            min_len: 4,
            max_len: 240,
            num_users: 2_000,
            num_items: 20_000,
            ..Default::default()
        };
        o.schema = "meituan-mixed".to_string();
        o.train.target_tokens = target_tokens;
        o.collect_gauc = false;
        o.overlap = true;
        o.cross_step = true;
        o.threads = threads;
        o.shard_capacity = 1 << 14;
        o.precision = precision;
        o.hot_threshold = hot_threshold;
        let engine = Engine::reference(7).unwrap();
        let t0 = Instant::now();
        let report = Trainer::new(o, engine).unwrap().run().unwrap();
        (report, t0.elapsed().as_secs_f64(), rss_bytes())
    };

    let mut rep = BenchReport::new("bench_precision");
    rep.add_metric("model", model.as_str().into());
    rep.add_metric("world", world.into());
    rep.add_metric("steps", steps.into());
    rep.add_metric("hot_threshold", (hot_threshold as usize).into());

    let (fp32, secs32, rss32) = run(PrecisionMode::Fp32);
    let (mixed, secs16, rss16) = run(PrecisionMode::Mixed);

    // --- correctness gates -------------------------------------------
    assert_eq!(fp32.precision, "fp32");
    assert_eq!(mixed.precision, "mixed");
    assert_eq!(
        (fp32.wire_fp16_row_bytes, fp32.wire_tag_bytes, fp32.quantize_ops),
        (0, 0, 0),
        "the fp32 baseline must keep every precision meter at zero"
    );
    assert!(
        mixed.hot_rows > 0 && mixed.cold_rows > 0,
        "census must see both classes: {} hot / {} cold",
        mixed.hot_rows,
        mixed.cold_rows
    );
    // The ID lane is a pure function of the seeded workload — identical
    // bytes either way — while cold rows at half width must strictly
    // shrink the reply and gradient lanes.
    assert_eq!(
        mixed.wire_payload_bytes[1], fp32.wire_payload_bytes[1],
        "the ID lane is workload-determined, not precision-determined"
    );
    let (reply16, reply32) = (mixed.wire_payload_bytes[2], fp32.wire_payload_bytes[2]);
    let (grad16, grad32) = (mixed.wire_payload_bytes[4], fp32.wire_payload_bytes[4]);
    assert!(
        reply16 < reply32,
        "cold replies must shrink the reply lane: {reply16} vs {reply32}"
    );
    assert!(
        grad16 < grad32,
        "cold gradient pushes must shrink the grad lane: {grad16} vs {grad32}"
    );
    // Effective storage strictly undercuts the all-FP32 footprint.
    let all_fp32: u64 = mixed
        .group_rows
        .iter()
        .zip(&mixed.group_dims)
        .map(|(&rows, &dim)| (rows * dim * 4) as u64)
        .sum();
    assert!(
        mixed.effective_value_bytes < all_fp32,
        "mixed storage must beat all-fp32: {} vs {all_fp32}",
        mixed.effective_value_bytes
    );
    // "At equal losses": quantizing cold rows to binary16 (rel err per
    // element ≤ 2⁻¹¹) must not move training quality materially.
    let (l32, l16) = (mean_loss(&fp32), mean_loss(&mixed));
    assert!(l32.is_finite() && l32 > 0.0 && l16.is_finite() && l16 > 0.0);
    let loss_drift = ((l16 - l32) / l32).abs();
    assert!(
        loss_drift < 0.05,
        "mixed precision moved the mean loss by {:.2}%: {l16} vs {l32}",
        loss_drift * 100.0
    );

    // --- quantization-error telemetry --------------------------------
    // The f16 grid's measured relative error over embedding-scale
    // values: bounded by the 11-bit significand, reported so a grid
    // regression (rounding-mode bug, truncation) is visible in the
    // artifact before it is visible in the loss. The 2⁻¹¹ bound only
    // holds for f16 *normals*, so the probe skips the band below
    // 1e-3 — samples under the minimum normal (2⁻¹⁴ ≈ 6.1e-5) land on
    // the coarser subnormal grid where relative error legitimately
    // reaches percent level.
    let mut rng = Xoshiro256::new(42);
    let (mut max_rel, mut sum_rel, mut n) = (0.0f64, 0.0f64, 0u64);
    for _ in 0..100_000 {
        let x = (rng.next_f32() - 0.5) * 0.2;
        if x.abs() < 1e-3 {
            continue;
        }
        let rel = (((quantize_f16(x) - x) / x) as f64).abs();
        max_rel = max_rel.max(rel);
        sum_rel += rel;
        n += 1;
    }
    let mean_rel = sum_rel / n as f64;
    assert!(
        max_rel <= 1.0 / 2048.0 + 1e-7,
        "f16 relative error exceeded the 11-bit bound: {max_rel}"
    );

    // --- report ------------------------------------------------------
    let sps32 = steps as f64 / secs32;
    let sps16 = steps as f64 / secs16;
    let mut tbl = Table::new(
        &format!(
            "Mixed precision ({model} × world {world}, {steps} steps, \
             hot threshold {hot_threshold})"
        ),
        &["precision", "steps/s", "mean loss", "reply MB", "grad MB", "stored MB", "rss MB"],
    );
    tbl.row(&[
        "fp32".into(),
        format!("{sps32:.2}"),
        format!("{l32:.5}"),
        format!("{:.3}", reply32 as f64 / 1e6),
        format!("{:.3}", grad32 as f64 / 1e6),
        format!("{:.3}", all_fp32 as f64 / 1e6),
        format!("{:.1}", rss32 as f64 / 1e6),
    ]);
    tbl.row(&[
        "mixed".into(),
        format!("{sps16:.2}"),
        format!("{l16:.5}"),
        format!("{:.3}", reply16 as f64 / 1e6),
        format!("{:.3}", grad16 as f64 / 1e6),
        format!("{:.3}", mixed.effective_value_bytes as f64 / 1e6),
        format!("{:.1}", rss16 as f64 / 1e6),
    ]);
    rep.add_table(tbl);

    rep.add_metric("steps_per_s_fp32", sps32.into());
    rep.add_metric("steps_per_s_mixed", sps16.into());
    rep.add_metric("mean_loss_fp32", l32.into());
    rep.add_metric("mean_loss_mixed", l16.into());
    rep.add_metric("loss_drift_pct", (loss_drift * 100.0).into());
    rep.add_metric("reply_bytes_fp32", (reply32 as f64).into());
    rep.add_metric("reply_bytes_mixed", (reply16 as f64).into());
    rep.add_metric("grad_bytes_fp32", (grad32 as f64).into());
    rep.add_metric("grad_bytes_mixed", (grad16 as f64).into());
    rep.add_metric("wire_fp32_row_bytes", (mixed.wire_fp32_row_bytes as f64).into());
    rep.add_metric("wire_fp16_row_bytes", (mixed.wire_fp16_row_bytes as f64).into());
    rep.add_metric("wire_tag_bytes", (mixed.wire_tag_bytes as f64).into());
    rep.add_metric("hot_rows", (mixed.hot_rows as usize).into());
    rep.add_metric("cold_rows", (mixed.cold_rows as usize).into());
    rep.add_metric("quantize_ops", (mixed.quantize_ops as usize).into());
    rep.add_metric(
        "effective_value_bytes",
        (mixed.effective_value_bytes as f64).into(),
    );
    rep.add_metric("all_fp32_value_bytes", (all_fp32 as f64).into());
    rep.add_metric("rss_bytes_after_fp32", (rss32 as f64).into());
    rep.add_metric("rss_bytes_after_mixed", (rss16 as f64).into());
    rep.add_metric("quant_rel_err_mean", mean_rel.into());
    rep.add_metric("quant_rel_err_max", max_rel.into());
    rep.save().unwrap();

    println!(
        "\nFP32-hot/FP16-cold storage and wire compression: reply lane \
         {} vs fp32, grad lane {}, stored bytes {} — at {} loss drift \
         and {} throughput.",
        pct_gain(reply16 as f64, reply32 as f64),
        pct_gain(grad16 as f64, grad32 as f64),
        pct_gain(mixed.effective_value_bytes as f64, all_fp32 as f64),
        format!("{:.3}%", loss_drift * 100.0),
        ratio(sps16, sps32)
    );
}
