//! Sharded lookup+apply throughput: serial reference engine vs the
//! pooled parallel sparse pipeline (PR 2's tentpole).
//!
//! One iteration = one stage-2 serve + optimizer round on a Zipf batch:
//! dedup → unique-row fetch (insert-on-miss) → occurrence-order
//! expansion → gradient scatter-accumulate → row-wise Adam apply.
//!
//! Rows:
//! - `reference 1t` — the pre-pool serial engine: hash dedup, per-id
//!   fetch (one stripe-lock acquisition per id), per-element gather /
//!   scatter, serial `SparseAdam::step`.
//! - `pooled Nt` — the batched pipeline on an N-thread [`WorkerPool`]:
//!   size-switched dedup kernel, stripe-bucketed batch fetch (one lock
//!   per stripe), chunked gather/scatter, `step_concurrent`.
//!
//! Outputs are bit-identical across rows (asserted on the expanded
//! rows); only the schedule differs.
//!
//! `--iters N` / `--occurrences N` (after `--`) shrink the run for CI.
//! `--calibrate` instead sweeps the four runtime-tunable thresholds
//! (`MTGR_DEDUP_SORT_THRESHOLD`, `MTGR_PAR_ROWS_THRESHOLD`,
//! `MTGR_PAR_FETCH_THRESHOLD`, `MTGR_PAR_DENSE_THRESHOLD`) across
//! input sizes, prints the serial/parallel crossover points measured
//! on THIS machine, and writes them to `calibration.json` next to the
//! working directory so a deployment can compare them against the
//! baked defaults in `util::tuning::calibrated` and export the env
//! overrides without recompiling.

use mtgrboost::embedding::concurrent::{ConcurrentDynamicTable, PAR_FETCH};
use mtgrboost::embedding::dedup::{
    gather_rows, gather_rows_par, scatter_accumulate, scatter_accumulate_par, Dedup, DEDUP_SORT,
    PAR_ROWS,
};
use mtgrboost::embedding::dynamic_table::DynamicTableConfig;
use mtgrboost::embedding::EmbeddingStore;
use mtgrboost::optim::adam::{AdamParams, DenseAdam, SparseAdam, PAR_DENSE};
use mtgrboost::util::bench::{bench_fn, ratio, BenchReport, Table};
use mtgrboost::util::json::Json;
use mtgrboost::util::cli::Args;
use mtgrboost::util::pool::WorkerPool;
use mtgrboost::util::rng::{Xoshiro256, Zipf};

const DIM: usize = 64;

fn table() -> ConcurrentDynamicTable {
    ConcurrentDynamicTable::new(
        DynamicTableConfig::new(DIM)
            .with_capacity(1 << 16)
            .with_seed(42),
        8,
    )
}

fn zipf_ids(n: usize, seed: u64) -> Vec<u64> {
    let z = Zipf::new(40_000, 1.05);
    let mut rng = Xoshiro256::new(seed);
    (0..n).map(|_| z.sample(&mut rng) as u64).collect()
}

/// One serial-reference round; returns the expanded occurrence rows of
/// the first iteration for the cross-variant equality check.
fn reference_round(
    t: &mut ConcurrentDynamicTable,
    opt: &mut SparseAdam,
    ids: &[u64],
    grads: &[f32],
) -> Vec<f32> {
    let d = Dedup::of_hash(ids);
    let mut unique_rows = vec![0.0f32; d.unique.len() * DIM];
    for (i, &id) in d.unique.iter().enumerate() {
        EmbeddingStore::lookup_or_insert(t, id, &mut unique_rows[i * DIM..(i + 1) * DIM]);
    }
    let mut expanded = vec![0.0f32; ids.len() * DIM];
    gather_rows(&unique_rows, DIM, &d.inverse, &mut expanded);
    let mut agg = vec![0.0f32; d.unique.len() * DIM];
    scatter_accumulate(grads, DIM, &d.inverse, &mut agg);
    opt.step(t, &d.unique, &agg, 1.0);
    expanded
}

/// One pooled round (same math, batched + parallel kernels).
fn pooled_round(
    pool: &WorkerPool,
    t: &ConcurrentDynamicTable,
    opt: &mut SparseAdam,
    ids: &[u64],
    grads: &[f32],
) -> Vec<f32> {
    let d = Dedup::of_auto(ids, Some(pool));
    let mut unique_rows = vec![0.0f32; d.unique.len() * DIM];
    t.fetch_rows_shared(&d.unique, true, &mut unique_rows, Some(pool));
    let mut expanded = vec![0.0f32; ids.len() * DIM];
    gather_rows_par(&unique_rows, DIM, &d.inverse, &mut expanded, Some(pool));
    let mut agg = vec![0.0f32; d.unique.len() * DIM];
    scatter_accumulate_par(grads, DIM, &d.inverse, &mut agg, Some(pool));
    opt.step_concurrent(pool, t, &d.unique, &agg, 1.0);
    expanded
}

/// Mean seconds of `f` over `iters` runs (1 warmup), for the sweep.
fn time_it(iters: usize, mut f: impl FnMut()) -> f64 {
    f();
    let t0 = std::time::Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_secs_f64() / iters as f64
}

/// Sweep the tunable thresholds: at each input size, time the serial
/// kernel against the parallel kernel (thresholds forced low so the
/// parallel path always engages) and report the first size where
/// parallel wins — the machine's crossover point.
fn calibrate(iters: usize, threads: usize) {
    let pool = WorkerPool::new(threads);
    let mut rep = BenchReport::new("bench_parallel_lookup_calibration");
    rep.add_metric("threads", threads.into());
    let sizes = [512usize, 1024, 2048, 4096, 8192, 16384, 32768];

    // Force every parallel path on, so the sweep measures the kernels —
    // restore the defaults before saving suggestions.
    DEDUP_SORT.set(1);
    PAR_ROWS.set(1);
    PAR_FETCH.set(1);
    PAR_DENSE.set(1);

    let mut tbl = Table::new(
        &format!("Threshold calibration ({threads}-thread pool, µs per call)"),
        &["n", "dedup hash", "dedup sort-par", "gather ser", "gather par", "scatter ser",
          "scatter par", "fetch ser", "fetch par", "dense ser", "dense par"],
    );
    // dedup, rows (gather|scatter), fetch, dense adam
    let mut cross = [None::<usize>; 4];
    for &n in &sizes {
        let ids = zipf_ids(n, 11);
        let d = Dedup::of_hash(&ids);
        let rows: Vec<f32> = {
            let mut rng = Xoshiro256::new(3);
            (0..d.unique.len() * DIM).map(|_| rng.next_f32()).collect()
        };
        let grads: Vec<f32> = {
            let mut rng = Xoshiro256::new(4);
            (0..n * DIM).map(|_| rng.next_f32() - 0.5).collect()
        };
        let t_hash = time_it(iters, || {
            std::hint::black_box(Dedup::of_hash(&ids));
        });
        let t_sort = time_it(iters, || {
            std::hint::black_box(Dedup::of_sorted_with(&ids, Some(&pool)));
        });
        let mut out = vec![0.0f32; n * DIM];
        let t_gather_s = time_it(iters, || gather_rows(&rows, DIM, &d.inverse, &mut out));
        let t_gather_p = time_it(iters, || {
            gather_rows_par(&rows, DIM, &d.inverse, &mut out, Some(&pool))
        });
        let mut acc = vec![0.0f32; d.unique.len() * DIM];
        let t_scatter_s = time_it(iters, || {
            acc.fill(0.0);
            scatter_accumulate(&grads, DIM, &d.inverse, &mut acc);
        });
        let t_scatter_p = time_it(iters, || {
            acc.fill(0.0);
            scatter_accumulate_par(&grads, DIM, &d.inverse, &mut acc, Some(&pool));
        });
        let ft = table();
        let mut fetched = vec![0.0f32; n * DIM];
        let t_fetch_s = time_it(iters, || ft.fetch_rows_shared(&ids, true, &mut fetched, None));
        let t_fetch_p = time_it(iters, || {
            ft.fetch_rows_shared(&ids, true, &mut fetched, Some(&pool))
        });
        // Dense Adam over n parameters (the element-chunked pooled step
        // vs the serial loop; `n` doubles as the dense size axis).
        let mut dense_params: Vec<f32> = {
            let mut rng = Xoshiro256::new(5);
            (0..n).map(|_| rng.next_f32()).collect()
        };
        let dense_grads: Vec<f32> = {
            let mut rng = Xoshiro256::new(6);
            (0..n).map(|_| rng.next_f32() - 0.5).collect()
        };
        let mut dense_s = DenseAdam::new(n, AdamParams::default());
        let t_dense_s = time_it(iters, || {
            dense_s.step_pooled(&mut dense_params, &dense_grads, 1.0, None)
        });
        let mut dense_p = DenseAdam::new(n, AdamParams::default());
        let t_dense_p = time_it(iters, || {
            dense_p.step_pooled(&mut dense_params, &dense_grads, 1.0, Some(&pool))
        });
        if cross[0].is_none() && t_sort < t_hash {
            cross[0] = Some(n);
        }
        if cross[1].is_none() && t_gather_p < t_gather_s && t_scatter_p < t_scatter_s {
            cross[1] = Some(n);
        }
        if cross[2].is_none() && t_fetch_p < t_fetch_s {
            cross[2] = Some(n);
        }
        if cross[3].is_none() && t_dense_p < t_dense_s {
            cross[3] = Some(n);
        }
        let us = |t: f64| format!("{:.1}", t * 1e6);
        tbl.row(&[
            format!("{n}"),
            us(t_hash),
            us(t_sort),
            us(t_gather_s),
            us(t_gather_p),
            us(t_scatter_s),
            us(t_scatter_p),
            us(t_fetch_s),
            us(t_fetch_p),
            us(t_dense_s),
            us(t_dense_p),
        ]);
    }
    DEDUP_SORT.set(DEDUP_SORT.default_value());
    PAR_ROWS.set(PAR_ROWS.default_value());
    PAR_FETCH.set(PAR_FETCH.default_value());
    PAR_DENSE.set(PAR_DENSE.default_value());

    let names = [
        ("suggested_dedup_sort_threshold", &DEDUP_SORT),
        ("suggested_par_rows_threshold", &PAR_ROWS),
        ("suggested_par_fetch_threshold", &PAR_FETCH),
        ("suggested_par_dense_threshold", &PAR_DENSE),
    ];
    let mut cal = Json::obj();
    cal.set("threads", threads.into());
    for (i, (key, knob)) in names.iter().enumerate() {
        // "Not reached" reports a sentinel above the sweep ceiling:
        // keep the kernel serial on this machine.
        let suggested = cross[i].unwrap_or(1 << 20);
        rep.add_metric(key, suggested.into());
        cal.set(knob.env_var(), suggested.into());
        println!(
            "{key}: crossover ≈ {} (compiled default {})",
            cross[i]
                .map(|n| n.to_string())
                .unwrap_or_else(|| "not reached in sweep".into()),
            knob.default_value(),
        );
    }
    // The machine-local calibration artifact: env-var name → measured
    // crossover, ready to `export` (or to diff against the baked
    // defaults in `util::tuning::calibrated`).
    std::fs::write("calibration.json", cal.pretty()).unwrap();
    println!("saved calibration.json");
    rep.add_table(tbl);
    rep.save().unwrap();
}

fn main() {
    // `cargo bench` passes a bare `--bench` to harness-false binaries;
    // declare it a value-less flag so it cannot swallow `--iters`.
    let args = Args::from_env(&["bench", "calibrate"]);
    let iters = args.get_usize("iters", 20);
    if args.has_flag("calibrate") {
        calibrate(iters.max(5), args.get_usize("threads", 4));
        return;
    }
    let n = args.get_usize("occurrences", 120_000);
    let ids = zipf_ids(n, 7);
    let grads: Vec<f32> = {
        let mut rng = Xoshiro256::new(11);
        (0..n * DIM).map(|_| rng.next_f32() - 0.5).collect()
    };

    let mut rep = BenchReport::new("bench_parallel_lookup");
    rep.add_metric(
        "dedup_kernel",
        format!("{:?}", Dedup::kernel_for(n)).as_str().into(),
    );
    let mut tbl = Table::new(
        &format!("Sharded lookup+apply throughput ({n} occurrences/round, dim {DIM})"),
        &["engine", "occ/s", "vs reference"],
    );

    // Serial reference engine.
    let mut ref_table = table();
    let mut ref_opt = SparseAdam::new(DIM, AdamParams::default());
    let ref_expanded = reference_round(&mut ref_table, &mut ref_opt, &ids, &grads);
    let r = bench_fn("reference 1t", 1, iters, |_| {
        let out = reference_round(&mut ref_table, &mut ref_opt, &ids, &grads);
        std::hint::black_box(out);
    });
    let ref_thpt = n as f64 / r.summary.mean;
    tbl.row(&[
        "reference 1t".into(),
        format!("{ref_thpt:.0}"),
        "1.00x".into(),
    ]);

    let mut speedup_4t = 0.0;
    for threads in [1usize, 2, 4] {
        let pool = WorkerPool::new(threads);
        let pt = table();
        let mut opt = SparseAdam::new(DIM, AdamParams::default());
        let expanded = pooled_round(&pool, &pt, &mut opt, &ids, &grads);
        assert_eq!(
            expanded, ref_expanded,
            "pooled pipeline must be bit-identical to the reference"
        );
        let name = format!("pooled {threads}t");
        let r = bench_fn(&name, 1, iters, |_| {
            let out = pooled_round(&pool, &pt, &mut opt, &ids, &grads);
            std::hint::black_box(out);
        });
        let thpt = n as f64 / r.summary.mean;
        let speed = thpt / ref_thpt;
        if threads == 4 {
            speedup_4t = speed;
        }
        rep.add_metric(&format!("occ_per_s_{threads}t"), thpt.into());
        tbl.row(&[name, format!("{thpt:.0}"), ratio(thpt, ref_thpt)]);
    }
    rep.add_metric("occ_per_s_reference", ref_thpt.into());
    rep.add_metric("speedup_4t_vs_reference", speedup_4t.into());
    rep.add_table(tbl);
    rep.save().unwrap();
    println!(
        "\nThe pooled pipeline batches stripe locking, switches the dedup \
         kernel by size, and fans fetch/gather/scatter/Adam across the \
         pool; at 4 threads it should clear 2x the serial reference."
    );
}
