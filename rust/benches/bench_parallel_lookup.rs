//! Sharded lookup+apply throughput: serial reference engine vs the
//! pooled parallel sparse pipeline (PR 2's tentpole).
//!
//! One iteration = one stage-2 serve + optimizer round on a Zipf batch:
//! dedup → unique-row fetch (insert-on-miss) → occurrence-order
//! expansion → gradient scatter-accumulate → row-wise Adam apply.
//!
//! Rows:
//! - `reference 1t` — the pre-pool serial engine: hash dedup, per-id
//!   fetch (one stripe-lock acquisition per id), per-element gather /
//!   scatter, serial `SparseAdam::step`.
//! - `pooled Nt` — the batched pipeline on an N-thread [`WorkerPool`]:
//!   size-switched dedup kernel, stripe-bucketed batch fetch (one lock
//!   per stripe), chunked gather/scatter, `step_concurrent`.
//!
//! Outputs are bit-identical across rows (asserted on the expanded
//! rows); only the schedule differs.
//!
//! `--iters N` / `--occurrences N` (after `--`) shrink the run for CI.

use mtgrboost::embedding::concurrent::ConcurrentDynamicTable;
use mtgrboost::embedding::dedup::{
    gather_rows, gather_rows_par, scatter_accumulate, scatter_accumulate_par, Dedup,
};
use mtgrboost::embedding::dynamic_table::DynamicTableConfig;
use mtgrboost::embedding::EmbeddingStore;
use mtgrboost::optim::adam::{AdamParams, SparseAdam};
use mtgrboost::util::bench::{bench_fn, ratio, BenchReport, Table};
use mtgrboost::util::cli::Args;
use mtgrboost::util::pool::WorkerPool;
use mtgrboost::util::rng::{Xoshiro256, Zipf};

const DIM: usize = 64;

fn table() -> ConcurrentDynamicTable {
    ConcurrentDynamicTable::new(
        DynamicTableConfig::new(DIM)
            .with_capacity(1 << 16)
            .with_seed(42),
        8,
    )
}

fn zipf_ids(n: usize, seed: u64) -> Vec<u64> {
    let z = Zipf::new(40_000, 1.05);
    let mut rng = Xoshiro256::new(seed);
    (0..n).map(|_| z.sample(&mut rng) as u64).collect()
}

/// One serial-reference round; returns the expanded occurrence rows of
/// the first iteration for the cross-variant equality check.
fn reference_round(
    t: &mut ConcurrentDynamicTable,
    opt: &mut SparseAdam,
    ids: &[u64],
    grads: &[f32],
) -> Vec<f32> {
    let d = Dedup::of_hash(ids);
    let mut unique_rows = vec![0.0f32; d.unique.len() * DIM];
    for (i, &id) in d.unique.iter().enumerate() {
        EmbeddingStore::lookup_or_insert(t, id, &mut unique_rows[i * DIM..(i + 1) * DIM]);
    }
    let mut expanded = vec![0.0f32; ids.len() * DIM];
    gather_rows(&unique_rows, DIM, &d.inverse, &mut expanded);
    let mut agg = vec![0.0f32; d.unique.len() * DIM];
    scatter_accumulate(grads, DIM, &d.inverse, &mut agg);
    opt.step(t, &d.unique, &agg, 1.0);
    expanded
}

/// One pooled round (same math, batched + parallel kernels).
fn pooled_round(
    pool: &WorkerPool,
    t: &ConcurrentDynamicTable,
    opt: &mut SparseAdam,
    ids: &[u64],
    grads: &[f32],
) -> Vec<f32> {
    let d = Dedup::of_auto(ids, Some(pool));
    let mut unique_rows = vec![0.0f32; d.unique.len() * DIM];
    t.fetch_rows_shared(&d.unique, true, &mut unique_rows, Some(pool));
    let mut expanded = vec![0.0f32; ids.len() * DIM];
    gather_rows_par(&unique_rows, DIM, &d.inverse, &mut expanded, Some(pool));
    let mut agg = vec![0.0f32; d.unique.len() * DIM];
    scatter_accumulate_par(grads, DIM, &d.inverse, &mut agg, Some(pool));
    opt.step_concurrent(pool, t, &d.unique, &agg, 1.0);
    expanded
}

fn main() {
    // `cargo bench` passes a bare `--bench` to harness-false binaries;
    // declare it a value-less flag so it cannot swallow `--iters`.
    let args = Args::from_env(&["bench"]);
    let iters = args.get_usize("iters", 20);
    let n = args.get_usize("occurrences", 120_000);
    let ids = zipf_ids(n, 7);
    let grads: Vec<f32> = {
        let mut rng = Xoshiro256::new(11);
        (0..n * DIM).map(|_| rng.next_f32() - 0.5).collect()
    };

    let mut rep = BenchReport::new("bench_parallel_lookup");
    rep.add_metric(
        "dedup_kernel",
        format!("{:?}", Dedup::kernel_for(n)).as_str().into(),
    );
    let mut tbl = Table::new(
        &format!("Sharded lookup+apply throughput ({n} occurrences/round, dim {DIM})"),
        &["engine", "occ/s", "vs reference"],
    );

    // Serial reference engine.
    let mut ref_table = table();
    let mut ref_opt = SparseAdam::new(DIM, AdamParams::default());
    let ref_expanded = reference_round(&mut ref_table, &mut ref_opt, &ids, &grads);
    let r = bench_fn("reference 1t", 1, iters, |_| {
        let out = reference_round(&mut ref_table, &mut ref_opt, &ids, &grads);
        std::hint::black_box(out);
    });
    let ref_thpt = n as f64 / r.summary.mean;
    tbl.row(&[
        "reference 1t".into(),
        format!("{ref_thpt:.0}"),
        "1.00x".into(),
    ]);

    let mut speedup_4t = 0.0;
    for threads in [1usize, 2, 4] {
        let pool = WorkerPool::new(threads);
        let pt = table();
        let mut opt = SparseAdam::new(DIM, AdamParams::default());
        let expanded = pooled_round(&pool, &pt, &mut opt, &ids, &grads);
        assert_eq!(
            expanded, ref_expanded,
            "pooled pipeline must be bit-identical to the reference"
        );
        let name = format!("pooled {threads}t");
        let r = bench_fn(&name, 1, iters, |_| {
            let out = pooled_round(&pool, &pt, &mut opt, &ids, &grads);
            std::hint::black_box(out);
        });
        let thpt = n as f64 / r.summary.mean;
        let speed = thpt / ref_thpt;
        if threads == 4 {
            speedup_4t = speed;
        }
        rep.add_metric(&format!("occ_per_s_{threads}t"), thpt.into());
        tbl.row(&[name, format!("{thpt:.0}"), ratio(thpt, ref_thpt)]);
    }
    rep.add_metric("occ_per_s_reference", ref_thpt.into());
    rep.add_metric("speedup_4t_vs_reference", speedup_4t.into());
    rep.add_table(tbl);
    rep.save().unwrap();
    println!(
        "\nThe pooled pipeline batches stripe locking, switches the dedup \
         kernel by size, and fans fetch/gather/scatter/Adam across the \
         pool; at 4 threads it should clear 2x the serial reference."
    );
}
