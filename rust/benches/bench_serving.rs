//! Serving-replica latency/throughput versus `--sync-interval`: train
//! the same online run twice (same total steps, different delta
//! cadence), then drive each sync dir with identical generated traffic
//! through [`mtgrboost::serve::run_serve`] and report p50/p99 request
//! latency, achieved QPS and cache hit rate per interval.
//!
//! Correctness is asserted, not assumed:
//! * each replica's content checksum equals its trainer report's
//!   `embedding_checksum` bit-for-bit (the sync chain reconstructs the
//!   trained state exactly);
//! * both serve runs produce the **bit-identical** logits sum — how the
//!   sync was chunked into deltas must not change what gets served;
//! * compacting each chain and cold-starting a replica from the fresh
//!   base alone reproduces the same checksum (compaction lost nothing).
//!
//! CLI (after `--`): `--requests N` (default 2000), `--micro-batch N`
//! (default 8), `--steps N` (default 40, divisible by both intervals),
//! `--sync-interval-short N` (default 5), `--sync-interval-long N`
//! (default 10), `--model NAME` (default tiny), `--world N` (default 2),
//! `--target-tokens N` (default 512), `--qps F` (default 4000).

use std::path::PathBuf;

use mtgrboost::online::{AdmissionConfig, OnlineOptions};
use mtgrboost::runtime::Engine;
use mtgrboost::serve::{
    compact_chain, run_serve, CompactOptions, ReplicaOptions, ServeOptions, ServingReplica,
    TrafficConfig,
};
use mtgrboost::train::{TrainReport, Trainer, TrainerOptions};
use mtgrboost::util::bench::{BenchReport, Table};
use mtgrboost::util::cli::Args;

struct Bench {
    model: String,
    world: usize,
    steps: usize,
    target_tokens: usize,
}

impl Bench {
    /// Online-train `self.steps` steps, publishing a delta every
    /// `sync_interval` of them into a fresh sync dir.
    fn train(&self, sync_interval: usize) -> (TrainReport, PathBuf) {
        assert_eq!(
            self.steps % sync_interval,
            0,
            "--steps must be divisible by sync interval {sync_interval}"
        );
        let dir = tmp(&format!("s{sync_interval}"));
        let mut o = TrainerOptions::new(&self.model, self.world, 0);
        o.train.target_tokens = self.target_tokens;
        o.generator.len_mu = 3.0;
        o.generator.max_len = 64;
        o.generator.new_user_rate = 0.3;
        o.generator.new_item_rate = 0.3;
        o.collect_gauc = false;
        o.log_every = self.steps;
        let mut online = OnlineOptions::new(sync_interval);
        online.intervals = self.steps / sync_interval;
        // TTL sweeps fire at sync boundaries, so ANY nonzero TTL makes
        // the final state depend on the cadence under comparison. Keep
        // it off here — the cross-cadence bit-identity assertions are
        // the point; expiry/removal replay is covered by the serving
        // tests and the serve_loop example.
        online.feature_ttl = 0;
        online.admission = Some(AdmissionConfig::new(2, 0.1));
        online.day_every = 2;
        online.sync_dir = Some(dir.clone());
        o.online = Some(online);
        let report = Trainer::new(o, Engine::reference(7).unwrap())
            .unwrap()
            .run()
            .unwrap();
        (report, dir)
    }
}

fn tmp(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("mtgr_bench_serving_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    d
}

fn main() {
    // `cargo bench` passes a bare `--bench` to harness-false binaries;
    // declare it a value-less flag so it cannot swallow `--requests`.
    let args = Args::from_env(&["bench"]);
    let bench = Bench {
        model: args.get_or("model", "tiny"),
        world: args.get_usize("world", 2),
        steps: args.get_usize("steps", 40),
        target_tokens: args.get_usize("target-tokens", 512),
    };
    let requests = args.get_usize("requests", 2000);
    let micro_batch = args.get_usize("micro-batch", 8);
    let intervals = [
        args.get_usize("sync-interval-short", 5),
        args.get_usize("sync-interval-long", 10),
    ];
    let qps = args.get_f64("qps", 4000.0);

    let mut rep = BenchReport::new("bench_serving");
    rep.add_metric("model", bench.model.as_str().into());
    rep.add_metric("world", bench.world.into());
    rep.add_metric("steps", bench.steps.into());
    rep.add_metric("requests", requests.into());
    rep.add_metric("micro_batch", micro_batch.into());
    let mut tbl = Table::new(
        &format!(
            "Serving vs --sync-interval ({} × world {}, {} steps, {} requests × {} ids)",
            bench.model,
            bench.world,
            bench.steps,
            requests,
            TrafficConfig::default().ids_per_request
        ),
        &[
            "sync interval",
            "deltas",
            "p50 ms",
            "p99 ms",
            "req/s",
            "cache hit %",
        ],
    );

    let mut ref_checksum: Option<u64> = None;
    let mut ref_logits: Option<u64> = None;
    for &interval in &intervals {
        let (train_report, dir) = bench.train(interval);
        // Same steps + TTL ⇒ the trained state is cadence-independent.
        if let Some(c) = ref_checksum {
            assert_eq!(
                c, train_report.embedding_checksum,
                "sync cadence changed training numerics"
            );
        } else {
            ref_checksum = Some(train_report.embedding_checksum);
        }

        let engine = Engine::reference(7).unwrap();
        let opts = ServeOptions {
            requests,
            micro_batch,
            refresh_every: 256,
            compact_every: 0, // measure the serve loop, compact after
            traffic: TrafficConfig {
                users: 100_000,
                qps,
                day_seconds: 2.0,
                ..TrafficConfig::default()
            },
            ..ServeOptions::default()
        };
        let report = run_serve(&dir, &engine, &opts).unwrap();
        assert_eq!(
            report.embedding_checksum, train_report.embedding_checksum,
            "sync_interval {interval}: replica diverged from the trainer"
        );
        assert_eq!(
            report.applied_seq as usize,
            bench.steps / interval,
            "sync_interval {interval}: wrong delta count applied"
        );
        if let Some(l) = ref_logits {
            assert_eq!(
                l,
                report.logits_sum.to_bits(),
                "served predictions must not depend on delta cadence"
            );
        } else {
            ref_logits = Some(report.logits_sum.to_bits());
        }

        // Fold the chain and cold-start from the base alone: same state.
        let folded = compact_chain(&dir, &CompactOptions::default())
            .unwrap()
            .expect("a non-empty chain to fold");
        assert_eq!(folded.checksum, train_report.embedding_checksum);
        let cold = ServingReplica::open(&dir, ReplicaOptions::default()).unwrap();
        assert_eq!(cold.content_checksum(), train_report.embedding_checksum);
        std::fs::remove_dir_all(&dir).ok();

        rep.add_metric(&format!("deltas_s{interval}"), (report.applied_seq as usize).into());
        rep.add_metric(&format!("latency_p50_ms_s{interval}"), report.latency_ms.p50.into());
        rep.add_metric(&format!("latency_p99_ms_s{interval}"), report.latency_ms.p99.into());
        rep.add_metric(&format!("latency_mean_ms_s{interval}"), report.latency_ms.mean.into());
        rep.add_metric(&format!("achieved_qps_s{interval}"), report.achieved_qps.into());
        rep.add_metric(&format!("offered_qps_s{interval}"), report.offered_qps.into());
        rep.add_metric(&format!("cache_hit_rate_s{interval}"), report.cache_hit_rate.into());
        rep.add_metric(
            &format!("compacted_rows_s{interval}"),
            folded.rows.into(),
        );
        tbl.row(&[
            format!("{interval}"),
            format!("{}", report.applied_seq),
            format!("{:.3}", report.latency_ms.p50),
            format!("{:.3}", report.latency_ms.p99),
            format!("{:.0}", report.achieved_qps),
            format!("{:.1}", report.cache_hit_rate * 100.0),
        ]);
    }

    rep.add_table(tbl);
    rep.save().unwrap();
    println!(
        "\nShorter sync intervals mean longer delta chains for the same trained \
         state — bootstrap and refresh fold more snapshots — but identical \
         served bytes (asserted bit-for-bit) and, after compaction, the same \
         single-base cold start."
    );
}
