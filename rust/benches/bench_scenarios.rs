//! Scenario engine sweep: run every `--scenario` preset for a few
//! steps, assert the bit-identity contract at `--threads {1,4}`, and
//! record the per-scenario telemetry (batcher carry-over/fill, peak
//! resident rows, admission/eviction churn) as `BENCH_scenarios.json`.
//!
//! This is the CI `scenario_smoke` payload: each preset must (a) train,
//! (b) produce identical per-step losses, telemetry and embedding
//! checksums across thread counts, and (c) actually engage the
//! machinery it claims to stress (skew-storm carries tokens over,
//! multi-tenant evicts against its row budget, the online storms admit
//! and reject).
//!
//! CLI (after `--`): `--steps N` (default 8, offline presets),
//! `--sync-interval N` (default 4) and `--intervals N` (default 2) for
//! the online presets, `--world N` (default 2).

use std::time::Instant;

use mtgrboost::online::OnlineOptions;
use mtgrboost::runtime::Engine;
use mtgrboost::scenario::Scenario;
use mtgrboost::train::{TrainReport, Trainer, TrainerOptions};
use mtgrboost::util::bench::{BenchReport, Table};
use mtgrboost::util::cli::Args;

struct Bench {
    world: usize,
    steps: usize,
    sync_interval: usize,
    intervals: usize,
}

impl Bench {
    fn run(&self, name: &str, threads: usize) -> (TrainReport, f64) {
        let sc = Scenario::by_name(name).unwrap();
        let online = sc.requires_online;
        let mut o = TrainerOptions::new("tiny", self.world, if online { 0 } else { self.steps });
        if online {
            let mut oo = OnlineOptions::new(self.sync_interval);
            oo.intervals = self.intervals;
            o.online = Some(oo);
        }
        o.scenario = Some(sc);
        o.collect_gauc = false;
        o.threads = threads;
        o.train.target_tokens = 2048;
        o.shard_capacity = 1 << 12;
        // Bounded ID spaces for the presets that don't override them,
        // so the smoke run revisits IDs within a few steps.
        o.generator.num_users = 2_000;
        o.generator.num_items = 20_000;
        let engine = Engine::reference(7).unwrap();
        let t0 = Instant::now();
        let report = Trainer::new(o, engine).unwrap().run().unwrap();
        (report, t0.elapsed().as_secs_f64())
    }
}

/// Bit-level fingerprint: per-step losses plus the scenario telemetry
/// lanes — all of it must be identical across `--threads`.
fn fingerprint(r: &TrainReport) -> (Vec<[u64; 6]>, u64) {
    (
        r.steps
            .iter()
            .map(|s| {
                [
                    s.loss_ctr.to_bits(),
                    s.samples,
                    s.batcher_carryover,
                    s.resident_rows,
                    s.online_day,
                    s.evictions,
                ]
            })
            .collect(),
        r.embedding_checksum,
    )
}

fn main() {
    let args = Args::from_env(&["bench"]);
    let bench = Bench {
        world: args.get_usize("world", 2),
        steps: args.get_usize("steps", 8),
        sync_interval: args.get_usize("sync-interval", 4),
        intervals: args.get_usize("intervals", 2),
    };

    let mut rep = BenchReport::new("BENCH_scenarios");
    rep.add_metric("world", bench.world.into());
    let mut tbl = Table::new(
        "Scenario sweep (tiny model, bit-identity asserted at threads {1,4})",
        &[
            "scenario", "steps", "steps/s", "carryover", "fill", "peak rows", "evict",
        ],
    );

    for &name in Scenario::preset_names() {
        let (r1, _) = bench.run(name, 1);
        let (r4, secs) = bench.run(name, 4);
        assert_eq!(
            fingerprint(&r1),
            fingerprint(&r4),
            "scenario `{name}` diverged between --threads 1 and 4"
        );
        assert_eq!(r1.scenario.as_deref(), Some(name), "report labeled");

        // Each preset must engage the machinery it stresses.
        match name {
            "skew-storm" => assert!(
                r1.batcher_carryover_mean > 0.0,
                "skew-storm never carried tokens over"
            ),
            "multi-tenant" => assert!(
                r1.total_evictions > 0,
                "multi-tenant row budget never evicted"
            ),
            "churn-storm" | "soak" => {
                assert!(r1.online_admitted > 0, "{name}: no admissions");
                assert!(r1.online_rejected > 0, "{name}: admission filtered nothing");
            }
            other => unreachable!("unknown preset {other}"),
        }

        let n_steps = r1.steps.len();
        let steps_per_s = n_steps as f64 / secs.max(1e-9);
        rep.add_metric(&format!("{name}_steps_per_s"), steps_per_s.into());
        rep.add_metric(
            &format!("{name}_peak_resident_rows"),
            (r1.peak_resident_rows as f64).into(),
        );
        rep.add_metric(
            &format!("{name}_batcher_carryover_mean"),
            r1.batcher_carryover_mean.into(),
        );
        rep.add_metric(
            &format!("{name}_batcher_fill_mean"),
            r1.batcher_fill_mean.into(),
        );
        rep.add_metric(
            &format!("{name}_evictions"),
            (r1.total_evictions as f64).into(),
        );
        rep.add_metric(
            &format!("{name}_online_admit_reject"),
            format!("{}/{}", r1.online_admitted, r1.online_rejected)
                .as_str()
                .into(),
        );
        tbl.row(&[
            name.into(),
            format!("{n_steps}"),
            format!("{steps_per_s:.2}"),
            format!("{:.0}", r1.batcher_carryover_mean),
            format!("{:.2}", r1.batcher_fill_mean),
            format!("{}", r1.peak_resident_rows),
            format!("{}", r1.total_evictions),
        ]);
    }

    rep.add_table(tbl);
    rep.save().unwrap();
    println!(
        "\nEvery scenario preset trained, stayed bit-identical across thread \
         counts, and engaged its target machinery — the scenario engine \
         composes with the existing stack instead of forking it."
    );
}
