//! Figure 12: per-phase time decomposition (embedding lookup / forward /
//! backward) for GRM 4G-1D and GRM 110G-64D, TorchRec baseline vs
//! MTGRBoost, over 100 steps.
//!
//! Simulated at paper scale (8 A100s); compute splits ≈ 1/3 forward,
//! 2/3 backward; "lookup" covers local table work plus both all-to-alls.
//! Additionally runs the *real* tiny model on the PJRT runtime to report
//! measured wall-clock phases (when artifacts are built).

use mtgrboost::config::ModelConfig;
use mtgrboost::embedding::dedup::DedupStrategy;
use mtgrboost::sim::{simulate, SimOptions};
use mtgrboost::util::bench::{BenchReport, Table};

fn configure(opts: &mut SimOptions, boosted: bool) {
    opts.sequence_balancing = boosted;
    opts.table_merging = boosted;
    opts.dedup = if boosted {
        DedupStrategy::TwoStage
    } else {
        DedupStrategy::None
    };
    opts.steps = 100;
}

fn main() {
    let mut table = Table::new(
        "Fig 12: cumulative phase times over 100 steps, 8 GPUs (simulated s)",
        &["config", "system", "lookup", "forward", "backward", "total"],
    );
    let mut rep = BenchReport::new("fig12_decomposition");
    for (label, model) in [
        ("4G 1D", ModelConfig::grm_4g()),
        ("110G 64D", ModelConfig::grm_110g().with_dim_factor(64)),
    ] {
        // Keep the embedding-memory budget fixed as dims scale.
        let mut totals = Vec::new();
        for boosted in [false, true] {
            let mut opts = SimOptions::new(model.clone(), 8);
            opts.resident_rows = 80_000;
            configure(&mut opts, boosted);
            let r = simulate(&opts);
            let mut lookup = 0.0;
            let mut fwd = 0.0;
            let mut bwd = 0.0;
            for s in &r.steps {
                // Synchronous steps are gated by the slowest device.
                let worst = s
                    .devices
                    .iter()
                    .map(|d| (d.lookup_s + d.comm_s, d.compute_s))
                    .fold((0.0f64, 0.0f64), |a, b| (a.0.max(b.0), a.1.max(b.1)));
                lookup += worst.0;
                fwd += worst.1 / 3.0;
                bwd += worst.1 * 2.0 / 3.0 + s.allreduce_s;
            }
            let total = lookup + fwd + bwd;
            totals.push(total);
            table.row(&[
                label.into(),
                if boosted { "MTGRBoost" } else { "TorchRec" }.into(),
                format!("{lookup:.2}"),
                format!("{fwd:.2}"),
                format!("{bwd:.2}"),
                format!("{total:.2}"),
            ]);
        }
        rep.add_metric(
            &format!("speedup_{}", label.replace(' ', "_")),
            (totals[0] / totals[1]).into(),
        );
    }
    rep.add_table(table);
    rep.save().unwrap();
    println!(
        "\nPaper: MTGRBoost is faster in every phase; gains grow with model \
         complexity and embedding dimension."
    );
}
