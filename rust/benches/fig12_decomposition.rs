//! Figure 12: per-phase time decomposition (embedding lookup / forward /
//! backward) for GRM 4G-1D and GRM 110G-64D, TorchRec baseline vs
//! MTGRBoost, over 100 steps.
//!
//! Simulated at paper scale (8 A100s); compute splits ≈ 1/3 forward,
//! 2/3 backward; "lookup" covers local table work plus both all-to-alls.
//! The overlap ablation additionally decomposes hidden communication
//! per lane: the ID exchange, the embedding reply (double-buffered
//! round), the backward gradient push (completed behind the next
//! micro-batch's forward), and the two cross-step boundary lanes (the
//! next step's first ID exchange and this step's last gradient push,
//! both riding the dense all-reduce).
//!
//! `--steps N` (after `--`) shrinks the run for CI smoke tests.

use mtgrboost::config::ModelConfig;
use mtgrboost::embedding::dedup::DedupStrategy;
use mtgrboost::sim::{simulate, SimOptions};
use mtgrboost::util::bench::{BenchReport, Table};
use mtgrboost::util::cli::Args;

fn configure(opts: &mut SimOptions, boosted: bool, overlap: bool, steps: usize) {
    opts.sequence_balancing = boosted;
    opts.table_merging = boosted;
    opts.dedup = if boosted {
        DedupStrategy::TwoStage
    } else {
        DedupStrategy::None
    };
    opts.overlap = overlap;
    // Cross-step pipelining rides the overlap ablation: the "+overlap"
    // system posts the next step's first ID exchange during the dense
    // all-reduce (the boundary lane).
    opts.cross_step = overlap;
    opts.steps = steps;
}

fn main() {
    // `cargo bench` passes a bare `--bench` to harness-false binaries;
    // declare it a value-less flag so it cannot swallow `--steps`.
    let args = Args::from_env(&["bench"]);
    let steps = args.get_usize("steps", 100);
    let mut table = Table::new(
        &format!("Fig 12: cumulative phase times over {steps} steps, 8 GPUs (simulated s)"),
        &[
            "config", "system", "lookup", "forward", "backward", "hid_id", "hid_reply",
            "hid_grad", "hid_bnd", "hid_bndg", "total",
        ],
    );
    let mut rep = BenchReport::new("fig12_decomposition");
    for (label, model) in [
        ("4G 1D", ModelConfig::grm_4g()),
        ("110G 64D", ModelConfig::grm_110g().with_dim_factor(64)),
    ] {
        // Keep the embedding-memory budget fixed as dims scale.
        let mut totals = Vec::new();
        let mut exposed_comm = Vec::new();
        let mut hidden_lanes = Vec::new();
        for (system, boosted, overlap) in [
            ("TorchRec", false, false),
            ("MTGRBoost", true, false),
            ("MTGRBoost+overlap", true, true),
        ] {
            let mut opts = SimOptions::new(model.clone(), 8);
            opts.resident_rows = 80_000;
            configure(&mut opts, boosted, overlap, steps);
            let r = simulate(&opts);
            let mut lookup = 0.0;
            let mut fwd = 0.0;
            let mut bwd = 0.0;
            let mut hid_id = 0.0;
            let mut hid_reply = 0.0;
            let mut hid_grad = 0.0;
            let mut hid_bnd = 0.0;
            let mut hid_bndg = 0.0;
            let mut comm = 0.0;
            for s in &r.steps {
                // Synchronous steps are gated by the slowest device.
                let worst = s
                    .devices
                    .iter()
                    .map(|d| (d.lookup_s + d.comm_s, d.compute_s))
                    .fold((0.0f64, 0.0f64), |a, b| (a.0.max(b.0), a.1.max(b.1)));
                lookup += worst.0;
                fwd += worst.1 / 3.0;
                bwd += worst.1 * 2.0 / 3.0 + s.allreduce_s;
                hid_id += s
                    .devices
                    .iter()
                    .map(|d| d.hidden_comm_s)
                    .fold(0.0f64, f64::max);
                hid_reply += s
                    .devices
                    .iter()
                    .map(|d| d.hidden_reply_s)
                    .fold(0.0f64, f64::max);
                hid_grad += s
                    .devices
                    .iter()
                    .map(|d| d.hidden_grad_s)
                    .fold(0.0f64, f64::max);
                hid_bnd += s
                    .devices
                    .iter()
                    .map(|d| d.hidden_boundary_s)
                    .fold(0.0f64, f64::max);
                hid_bndg += s
                    .devices
                    .iter()
                    .map(|d| d.hidden_boundary_grad_s)
                    .fold(0.0f64, f64::max);
                comm += s.devices.iter().map(|d| d.comm_s).fold(0.0f64, f64::max);
            }
            let total = lookup + fwd + bwd;
            totals.push(total);
            exposed_comm.push(comm);
            hidden_lanes.push((hid_id, hid_reply, hid_grad, hid_bnd, hid_bndg));
            table.row(&[
                label.into(),
                system.into(),
                format!("{lookup:.2}"),
                format!("{fwd:.2}"),
                format!("{bwd:.2}"),
                format!("{hid_id:.2}"),
                format!("{hid_reply:.2}"),
                format!("{hid_grad:.2}"),
                format!("{hid_bnd:.2}"),
                format!("{hid_bndg:.2}"),
                format!("{total:.2}"),
            ]);
        }
        let tag = label.replace(' ', "_");
        rep.add_metric(&format!("speedup_{tag}"), (totals[0] / totals[1]).into());
        // The overlap ablation: exposed communication must shrink when
        // the exchanges pipeline behind compute.
        rep.add_metric(
            &format!("exposed_comm_s_{tag}_overlap_off"),
            exposed_comm[1].into(),
        );
        rep.add_metric(
            &format!("exposed_comm_s_{tag}_overlap_on"),
            exposed_comm[2].into(),
        );
        let (hid_id, hid_reply, hid_grad, hid_bnd, hid_bndg) = hidden_lanes[2];
        rep.add_metric(&format!("hidden_id_s_{tag}_overlap_on"), hid_id.into());
        rep.add_metric(&format!("hidden_reply_s_{tag}_overlap_on"), hid_reply.into());
        rep.add_metric(&format!("hidden_grad_s_{tag}_overlap_on"), hid_grad.into());
        rep.add_metric(
            &format!("sim_hidden_boundary_s_{tag}_overlap_on"),
            hid_bnd.into(),
        );
        rep.add_metric(
            &format!("sim_hidden_boundary_grad_s_{tag}_overlap_on"),
            hid_bndg.into(),
        );
        assert!(
            exposed_comm[2] < exposed_comm[1],
            "overlap must reduce exposed communication ({} vs {})",
            exposed_comm[2],
            exposed_comm[1]
        );
        assert_eq!(
            hidden_lanes[1],
            (0.0, 0.0, 0.0, 0.0, 0.0),
            "no hidden time without overlap"
        );
        assert!(
            hid_bnd > 0.0,
            "cross-step overlap must hide boundary time on the ID lane"
        );
        assert!(
            hid_bndg > 0.0,
            "cross-step overlap must hide boundary time on the gradient lane"
        );
        if label == "4G 1D" {
            // Compute dominates every lane at 4G scale: the
            // double-buffered round must report hidden time on the
            // reply and gradient lanes, not just the ID exchange.
            assert!(hid_id > 0.0, "ID lane must hide time");
            assert!(hid_reply > 0.0, "reply lane must hide time");
            assert!(hid_grad > 0.0, "gradient lane must hide time");
        }
    }
    rep.add_table(table);

    // Real-table counters alongside the simulated decomposition: a
    // compact reference-engine run surfaces the ConcurrentDynamicTable
    // memory-pressure statistics (inserts / expansions / evictions)
    // into the same JSON artifact, so the perf trajectory can correlate
    // the simulated phase times with observed table behaviour.
    {
        use mtgrboost::data::generator::GeneratorConfig;
        use mtgrboost::runtime::Engine;
        use mtgrboost::train::{Trainer, TrainerOptions};
        let mut o = TrainerOptions::new("tiny", 2, steps.min(10));
        o.generator = GeneratorConfig {
            len_mu: 2.5,
            len_sigma: 0.5,
            min_len: 2,
            max_len: 60,
            num_users: 500,
            num_items: 300,
            ..Default::default()
        };
        o.train.target_tokens = 900;
        o.collect_gauc = false;
        let engine = Engine::reference(7).unwrap();
        let r = Trainer::new(o, engine).unwrap().run().unwrap();
        assert!(r.table_stats.inserts > 0, "real run must insert rows");
        rep.add_metric("real_table_rows", r.table_rows.into());
        rep.add_metric("real_table_inserts", r.table_stats.inserts.into());
        rep.add_metric("real_table_expansions", r.table_stats.expansions.into());
        rep.add_metric("real_table_evictions", r.table_stats.evictions.into());
    }
    rep.save().unwrap();
    println!(
        "\nPaper: MTGRBoost is faster in every phase; gains grow with model \
         complexity and embedding dimension. Overlap additionally hides the \
         ID exchange, the embedding reply and the gradient push behind \
         compute (`hid_*` columns)."
    );
}
