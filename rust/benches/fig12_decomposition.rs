//! Figure 12: per-phase time decomposition (embedding lookup / forward /
//! backward) for GRM 4G-1D and GRM 110G-64D, TorchRec baseline vs
//! MTGRBoost, over 100 steps.
//!
//! Simulated at paper scale (8 A100s); compute splits ≈ 1/3 forward,
//! 2/3 backward; "lookup" covers local table work plus both all-to-alls.
//! Additionally runs the *real* tiny model on the PJRT runtime to report
//! measured wall-clock phases (when artifacts are built).

use mtgrboost::config::ModelConfig;
use mtgrboost::embedding::dedup::DedupStrategy;
use mtgrboost::sim::{simulate, SimOptions};
use mtgrboost::util::bench::{BenchReport, Table};

fn configure(opts: &mut SimOptions, boosted: bool, overlap: bool) {
    opts.sequence_balancing = boosted;
    opts.table_merging = boosted;
    opts.dedup = if boosted {
        DedupStrategy::TwoStage
    } else {
        DedupStrategy::None
    };
    opts.overlap = overlap;
    opts.steps = 100;
}

fn main() {
    let mut table = Table::new(
        "Fig 12: cumulative phase times over 100 steps, 8 GPUs (simulated s)",
        &[
            "config", "system", "lookup", "forward", "backward", "hidden", "total",
        ],
    );
    let mut rep = BenchReport::new("fig12_decomposition");
    for (label, model) in [
        ("4G 1D", ModelConfig::grm_4g()),
        ("110G 64D", ModelConfig::grm_110g().with_dim_factor(64)),
    ] {
        // Keep the embedding-memory budget fixed as dims scale.
        let mut totals = Vec::new();
        let mut exposed_comm = Vec::new();
        for (system, boosted, overlap) in [
            ("TorchRec", false, false),
            ("MTGRBoost", true, false),
            ("MTGRBoost+overlap", true, true),
        ] {
            let mut opts = SimOptions::new(model.clone(), 8);
            opts.resident_rows = 80_000;
            configure(&mut opts, boosted, overlap);
            let r = simulate(&opts);
            let mut lookup = 0.0;
            let mut fwd = 0.0;
            let mut bwd = 0.0;
            let mut hidden = 0.0;
            let mut comm = 0.0;
            for s in &r.steps {
                // Synchronous steps are gated by the slowest device.
                let worst = s
                    .devices
                    .iter()
                    .map(|d| (d.lookup_s + d.comm_s, d.compute_s))
                    .fold((0.0f64, 0.0f64), |a, b| (a.0.max(b.0), a.1.max(b.1)));
                lookup += worst.0;
                fwd += worst.1 / 3.0;
                bwd += worst.1 * 2.0 / 3.0 + s.allreduce_s;
                hidden += s
                    .devices
                    .iter()
                    .map(|d| d.hidden_comm_s)
                    .fold(0.0f64, f64::max);
                comm += s.devices.iter().map(|d| d.comm_s).fold(0.0f64, f64::max);
            }
            let total = lookup + fwd + bwd;
            totals.push(total);
            exposed_comm.push(comm);
            table.row(&[
                label.into(),
                system.into(),
                format!("{lookup:.2}"),
                format!("{fwd:.2}"),
                format!("{bwd:.2}"),
                format!("{hidden:.2}"),
                format!("{total:.2}"),
            ]);
        }
        rep.add_metric(
            &format!("speedup_{}", label.replace(' ', "_")),
            (totals[0] / totals[1]).into(),
        );
        // The overlap ablation: exposed communication must shrink when
        // the ID exchange pipelines behind compute.
        rep.add_metric(
            &format!("exposed_comm_s_{}_overlap_off", label.replace(' ', "_")),
            exposed_comm[1].into(),
        );
        rep.add_metric(
            &format!("exposed_comm_s_{}_overlap_on", label.replace(' ', "_")),
            exposed_comm[2].into(),
        );
        assert!(
            exposed_comm[2] < exposed_comm[1],
            "overlap must reduce exposed communication ({} vs {})",
            exposed_comm[2],
            exposed_comm[1]
        );
    }
    rep.add_table(table);
    rep.save().unwrap();
    println!(
        "\nPaper: MTGRBoost is faster in every phase; gains grow with model \
         complexity and embedding dimension. Overlap additionally hides the \
         ID exchange behind compute (`hidden` column)."
    );
}
