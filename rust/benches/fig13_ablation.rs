//! Figure 13: ablation — incrementally enable table merging, two-stage
//! deduplication, then sequence balancing, for GRM 4G-1D and 110G-1D.
//!
//! Paper: each component contributes; combined speedup 1.60×–2.44× over
//! the TorchRec baseline, growing with computational complexity.

use mtgrboost::config::ModelConfig;
use mtgrboost::embedding::dedup::DedupStrategy;
use mtgrboost::sim::{simulate, SimOptions};
use mtgrboost::util::bench::{ratio, BenchReport, Table};

fn main() {
    let mut table = Table::new(
        "Fig 13: ablation (8 GPUs, simulated seq/s)",
        &["config", "variant", "seq/s", "vs baseline"],
    );
    let mut rep = BenchReport::new("fig13_ablation");
    for (label, model) in [
        ("4G 1D", ModelConfig::grm_4g()),
        ("110G 1D", ModelConfig::grm_110g()),
    ] {
        let variants: [(&str, Box<dyn Fn(&mut SimOptions)>); 4] = [
            (
                "baseline (TorchRec)",
                Box::new(|o: &mut SimOptions| {
                    o.sequence_balancing = false;
                    o.table_merging = false;
                    o.dedup = DedupStrategy::None;
                }),
            ),
            (
                "+ merge tables",
                Box::new(|o: &mut SimOptions| {
                    o.sequence_balancing = false;
                    o.table_merging = true;
                    o.dedup = DedupStrategy::None;
                }),
            ),
            (
                "+ two-stage dedup",
                Box::new(|o: &mut SimOptions| {
                    o.sequence_balancing = false;
                    o.table_merging = true;
                    o.dedup = DedupStrategy::TwoStage;
                }),
            ),
            (
                "+ seq balancing (full)",
                Box::new(|o: &mut SimOptions| {
                    o.sequence_balancing = true;
                    o.table_merging = true;
                    o.dedup = DedupStrategy::TwoStage;
                }),
            ),
        ];
        let mut base = None;
        for (name, cfg) in variants.iter() {
            let mut opts = SimOptions::new(model.clone(), 8);
            opts.steps = 40;
            cfg(&mut opts);
            let r = simulate(&opts);
            let b = *base.get_or_insert(r.throughput);
            table.row(&[
                label.into(),
                (*name).into(),
                format!("{:.0}", r.throughput),
                ratio(r.throughput, b),
            ]);
            if *name == "+ seq balancing (full)" {
                rep.add_metric(
                    &format!("full_speedup_{}", label.replace(' ', "_")),
                    (r.throughput / b).into(),
                );
            }
        }
    }
    rep.add_table(table);
    rep.add_metric("paper_range", "1.60x - 2.44x".into());
    rep.save().unwrap();
}
