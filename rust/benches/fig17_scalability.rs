//! Figure 17: scalability 8 → 128 GPUs.
//!
//! (a) fixed 1D embedding dim, complexities 4G vs 110G;
//! (b) fixed 4G complexity, dim factors 2D vs 64D.
//!
//! Paper: all configurations scale sublinearly; MTGRBoost reaches
//! 62.75%–78.5% of ideal speedup at 128 GPUs; scaling the embedding
//! dimension degrades speedup more than scaling FLOPs (sparse traffic
//! dominates the critical path).

use mtgrboost::config::ModelConfig;
use mtgrboost::sim::{simulate, SimOptions};
use mtgrboost::util::bench::{BenchReport, Table};

fn main() {
    let configs = [
        ("4G 1D", ModelConfig::grm_4g()),
        ("110G 1D", ModelConfig::grm_110g()),
        ("4G 2D", ModelConfig::grm_4g().with_dim_factor(2)),
        ("4G 64D", ModelConfig::grm_4g().with_dim_factor(64)),
    ];
    let worlds = [8usize, 16, 32, 64, 128];

    let mut rep = BenchReport::new("fig17_scalability");
    let mut table = Table::new(
        "Fig 17: speedup vs 8-GPU baseline (simulated)",
        &["config", "gpus", "seq/s", "speedup", "% of ideal"],
    );
    for (label, model) in configs {
        let mut base = None;
        let mut at128 = 0.0;
        for &world in &worlds {
            let mut opts = SimOptions::new(model.clone(), world);
            opts.steps = 20;
            opts.resident_rows = 1_000_000;
            let r = simulate(&opts);
            let b = *base.get_or_insert(r.throughput);
            let speedup = r.throughput / b;
            let ideal = world as f64 / 8.0;
            let pct = 100.0 * speedup / ideal;
            if world == 128 {
                at128 = pct;
            }
            table.row(&[
                label.into(),
                world.to_string(),
                format!("{:.0}", r.throughput),
                format!("{speedup:.2}x"),
                format!("{pct:.1}%"),
            ]);
        }
        rep.add_metric(
            &format!("pct_ideal_at_128_{}", label.replace(' ', "_")),
            at128.into(),
        );
    }
    rep.add_table(table);
    rep.add_metric("paper_range_at_128", "62.75% - 78.5%".into());
    rep.save().unwrap();
    println!(
        "\nPaper claims at 128 GPUs: 62.75%-78.5% of ideal; dim factor hurts \
         more than FLOPs."
    );
}
