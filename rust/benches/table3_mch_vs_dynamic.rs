//! Table 3: dynamic hash table vs TorchRec Managed Collision Handling
//! (MCH), over complexities {4G, 110G} × dim factors {1D, 8D, 64D}.
//!
//! Paper: dynamic table wins 1.47×–2.22× (grouped parallel probing vs
//! binary-search remap), and MCH OOMs at 110G-64D because it
//! pre-allocates its full remap + embedding capacity.
//!
//! Method: (1) measure the REAL per-op cost ratio between our actual
//! `MchTable` and `DynamicEmbeddingTable` implementations under a Zipf
//! workload — the mechanism behind the paper's gap; (2) compose it with
//! the simulated step decomposition: MCH multiplies the sparse phase
//! (table ops + exchanges) by the measured ratio, and the A100 memory
//! model decides the OOM cells; (3) rerun the micro-benchmark under the
//! `churn-storm` scenario's flash-sale ID stream (most draws mint fresh
//! IDs), where MCH's sorted remap pays an O(n) shifting insert per new
//! ID and its eviction passes fire continuously.

use mtgrboost::config::ModelConfig;
use mtgrboost::data::generator::GeneratorConfig;
use mtgrboost::embedding::dynamic_table::{DynamicEmbeddingTable, DynamicTableConfig};
use mtgrboost::embedding::mch::MchTable;
use mtgrboost::embedding::EmbeddingStore;
use mtgrboost::scenario::Scenario;
use mtgrboost::sim::{simulate, would_oom, SimOptions, TableBackend};
use mtgrboost::util::bench::{bench_fn, BenchReport, Table};
use mtgrboost::util::rng::{Xoshiro256, Zipf};

fn main() {
    let mut rep = BenchReport::new("table3_mch_vs_dynamic");

    // ---- part 1: real table micro-benchmark ---------------------------
    const DIM: usize = 16;
    const VOCAB: usize = 40_000;
    let zipf = Zipf::new(VOCAB, 1.05);
    let mut rng = Xoshiro256::new(7);
    let ids: Vec<u64> = (0..200_000)
        .map(|_| zipf.sample(&mut rng) as u64)
        .collect();

    let mut dynamic = DynamicEmbeddingTable::new(
        DynamicTableConfig::new(DIM).with_capacity(1024),
    );
    let mut mch = MchTable::new(DIM, VOCAB, 1);
    let mut buf = vec![0.0f32; DIM];
    let mut i = 0usize;
    let r_dyn = bench_fn("dynamic_table_lookup_or_insert", 1, 5, |_| {
        for _ in 0..ids.len() / 5 {
            dynamic.lookup_or_insert(ids[i % ids.len()], &mut buf);
            i += 1;
        }
    });
    i = 0;
    let r_mch = bench_fn("mch_lookup_or_insert", 1, 5, |_| {
        for _ in 0..ids.len() / 5 {
            mch.lookup_or_insert(ids[i % ids.len()], &mut buf);
            i += 1;
        }
    });
    let measured_ratio = r_mch.summary.mean / r_dyn.summary.mean;
    rep.add_metric("real_lookup_slowdown", measured_ratio.into());
    println!(
        "\nreal table micro-bench: MCH is {measured_ratio:.2}x slower than the \
         dynamic hash table\n"
    );

    // ---- part 2: composed Table 3 grid --------------------------------
    let mut table = Table::new(
        "Table 3: throughput (simulated seq/s), MCH vs dynamic",
        &["complexity", "dim", "MCH", "MTGRBoost", "gain"],
    );
    for (clabel, model) in [("4G", ModelConfig::grm_4g()), ("110G", ModelConfig::grm_110g())]
    {
        for dim_factor in [1usize, 8, 64] {
            let mut opts = SimOptions::new(model.clone().with_dim_factor(dim_factor), 8);
            opts.steps = 20;
            opts.resident_rows = 60_000;
            let r_dyn = simulate(&opts);
            // MCH memory: simulate with the MCH backend (pre-allocated
            // remap + value capacity).
            let mut mch_opts = opts.clone();
            mch_opts.backend = TableBackend::Mch;
            let r_mch_mem = simulate(&mch_opts);
            assert!(!would_oom(&r_dyn), "dynamic table must fit everywhere");

            // Compose step times: sparse phase (table ops + exchanges)
            // scales by the *measured* implementation ratio under MCH.
            let (mut t_dyn, mut t_mch) = (0.0f64, 0.0f64);
            let mut samples = 0u64;
            for s in &r_dyn.steps {
                let compute = s
                    .devices
                    .iter()
                    .map(|d| d.compute_s)
                    .fold(0.0f64, f64::max);
                let sparse = s
                    .devices
                    .iter()
                    .map(|d| d.lookup_s + d.comm_s)
                    .fold(0.0f64, f64::max);
                t_dyn += compute + sparse + s.allreduce_s;
                t_mch += compute + sparse * measured_ratio + s.allreduce_s;
                samples += s.devices.iter().map(|d| d.sequences as u64).sum::<u64>();
            }
            let thr_dyn = samples as f64 / t_dyn;
            let thr_mch = samples as f64 / t_mch;

            let (mch_cell, gain_cell) = if would_oom(&r_mch_mem) {
                ("OOM".to_string(), "-".to_string())
            } else {
                (
                    format!("{thr_mch:.0}"),
                    format!("{:+.1}%", 100.0 * (thr_dyn / thr_mch - 1.0)),
                )
            };
            table.row(&[
                clabel.into(),
                format!("{dim_factor}D"),
                mch_cell,
                format!("{thr_dyn:.0}"),
                gain_cell,
            ]);
            if would_oom(&r_mch_mem) {
                rep.add_metric(&format!("oom_{clabel}_{dim_factor}d"), true.into());
            } else {
                rep.add_metric(
                    &format!("gain_{clabel}_{dim_factor}d"),
                    (thr_dyn / thr_mch).into(),
                );
            }
        }
    }
    rep.add_table(table);
    rep.add_metric("paper_range", "1.47x - 2.22x, MCH OOM at 110G-64D".into());

    // ---- part 3: churn-storm rerun ------------------------------------
    // The scenario engine's flash-sale preset: most draws mint a
    // brand-new ID (its shaped `new_item_rate`), the rest revisit a
    // Zipf head over the already-minted space. Fresh IDs are MCH's
    // worst case — every one is an O(n) shifting insert into the
    // sorted remap, and the pre-allocated capacity forces continuous
    // eviction passes — while the dynamic hash table just probes.
    let mut churn_cfg = GeneratorConfig::default();
    Scenario::churn_storm().shape_generator(&mut churn_cfg);
    let mut rng = Xoshiro256::new(11);
    let revisit = Zipf::new(VOCAB, 1.05);
    let mut next_fresh = VOCAB as u64;
    let churn_ids: Vec<u64> = (0..200_000)
        .map(|_| {
            if rng.next_f64() < churn_cfg.new_item_rate {
                next_fresh += 1;
                next_fresh
            } else {
                // Revisit near the newest IDs (flash-sale recency bias).
                next_fresh - (revisit.sample(&mut rng) as u64).min(next_fresh - 1)
            }
        })
        .collect();

    let mut dyn_churn = DynamicEmbeddingTable::new(
        DynamicTableConfig::new(DIM)
            .with_capacity(1024)
            .with_max_rows(VOCAB),
    );
    let mut mch_churn = MchTable::new(DIM, VOCAB, 1);
    let mut i = 0usize;
    let r_dyn_churn = bench_fn("dynamic_table_churn_storm", 1, 5, |_| {
        for _ in 0..churn_ids.len() / 5 {
            dyn_churn.lookup_or_insert(churn_ids[i % churn_ids.len()], &mut buf);
            i += 1;
        }
    });
    i = 0;
    let r_mch_churn = bench_fn("mch_churn_storm", 1, 5, |_| {
        for _ in 0..churn_ids.len() / 5 {
            mch_churn.lookup_or_insert(churn_ids[i % churn_ids.len()], &mut buf);
            i += 1;
        }
    });
    let churn_ratio = r_mch_churn.summary.mean / r_dyn_churn.summary.mean;
    let mut churn_table = Table::new(
        "churn-storm rerun: per-table cost and eviction churn",
        &["table", "mean s/pass", "evictions", "resident"],
    );
    churn_table.row(&[
        "dynamic".into(),
        format!("{:.4}", r_dyn_churn.summary.mean),
        format!("{}", dyn_churn.stats.evictions),
        format!("{}", EmbeddingStore::len(&dyn_churn)),
    ]);
    churn_table.row(&[
        "mch".into(),
        format!("{:.4}", r_mch_churn.summary.mean),
        format!("{}", mch_churn.evictions),
        format!("{}", EmbeddingStore::len(&mch_churn)),
    ]);
    rep.add_table(churn_table);
    rep.add_metric("churn_lookup_slowdown", churn_ratio.into());
    rep.add_metric("churn_mch_evictions", (mch_churn.evictions as f64).into());
    rep.add_metric(
        "churn_dynamic_evictions",
        (dyn_churn.stats.evictions as f64).into(),
    );
    println!(
        "\nchurn-storm rerun: MCH is {churn_ratio:.2}x slower under the flash-sale \
         ID stream ({} MCH evictions vs {} dynamic)\n",
        mch_churn.evictions, dyn_churn.stats.evictions
    );

    rep.save().unwrap();
}
