//! Integration: the full distributed training loop over the real PJRT
//! runtime (requires `make artifacts`; tests skip otherwise).

use mtgrboost::config::TrainConfig;
use mtgrboost::data::generator::GeneratorConfig;
use mtgrboost::embedding::dedup::DedupStrategy;
use mtgrboost::runtime::Engine;
use mtgrboost::train::{Trainer, TrainerOptions};

fn engine() -> Option<Engine> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Engine::start(&dir).unwrap())
}

/// Short sequences so tests stay fast.
fn fast_gen() -> GeneratorConfig {
    GeneratorConfig {
        len_mu: 2.5, // mean length ≈ 13
        len_sigma: 0.5,
        min_len: 2,
        max_len: 60,
        num_users: 500,
        num_items: 300,
        ..Default::default()
    }
}

fn base_opts(world: usize, steps: usize) -> TrainerOptions {
    let mut o = TrainerOptions::new("tiny", world, steps);
    o.generator = fast_gen();
    o.train.target_tokens = 120;
    o.train.fixed_batch = 8;
    o.train.lr = 0.01; // short tests need visible learning
    o.shard_capacity = 512;
    o
}

#[test]
fn two_worker_training_runs_and_learns() {
    let Some(engine) = engine() else { return };
    let mut opts = base_opts(2, 40);
    opts.gauc_warmup = 15; // score the model only after some learning
    let report = Trainer::new(opts, engine).unwrap().run().unwrap();
    assert_eq!(report.steps.len(), 40);
    // Losses are finite and the model learns (mean of last 5 < first 5).
    let head: f64 = report.steps[..5].iter().map(|s| s.loss_ctr).sum::<f64>() / 5.0;
    let (tail_ctr, _) = report.final_losses();
    assert!(head.is_finite() && tail_ctr.is_finite());
    assert!(
        tail_ctr < head,
        "loss did not improve: {head:.4} -> {tail_ctr:.4}"
    );
    // Sparse tables actually filled.
    assert!(report.table_rows > 100, "rows = {}", report.table_rows);
    // GAUC is computable and better than random.
    let g = report.gauc_ctr.expect("gauc");
    assert!(g > 0.5, "GAUC {g:.3} should beat random after training");
    // Phase decomposition recorded all five phases.
    for phase in ["1_data", "2_lookup", "3_compute", "4_sparse_update", "5_dense_sync"] {
        assert!(report.phases.total(phase) > 0.0, "missing phase {phase}");
    }
}

#[test]
fn dedup_strategies_do_not_change_learning() {
    // The dedup path is a pure communication optimization: losses must
    // match bitwise-tolerantly between None and TwoStage.
    let Some(engine) = engine() else { return };
    let mut reports = Vec::new();
    for strategy in [DedupStrategy::None, DedupStrategy::TwoStage] {
        let mut opts = base_opts(2, 8);
        opts.train.dedup = strategy;
        opts.collect_gauc = false;
        let report = Trainer::new(opts, engine.clone()).unwrap().run().unwrap();
        reports.push(report);
    }
    for (a, b) in reports[0].steps.iter().zip(&reports[1].steps) {
        assert!(
            (a.loss_ctr - b.loss_ctr).abs() < 1e-4,
            "step {}: {} vs {}",
            a.step,
            a.loss_ctr,
            b.loss_ctr
        );
    }
    // But the communication volume differs drastically.
    assert!(reports[1].dedup_volume.ids_sent < reports[0].dedup_volume.ids_sent);
}

#[test]
fn sequence_balancing_reduces_token_spread() {
    let Some(engine) = engine() else { return };
    let spread = |balancing: bool| {
        let mut opts = base_opts(4, 12);
        opts.train.sequence_balancing = balancing;
        opts.collect_gauc = false;
        let report = Trainer::new(opts, engine.clone()).unwrap().run().unwrap();
        let mut rel = 0.0;
        for s in &report.steps {
            let max = *s.tokens.iter().max().unwrap() as f64;
            let min = *s.tokens.iter().min().unwrap() as f64;
            rel += (max - min) / max.max(1.0);
        }
        rel / report.steps.len() as f64
    };
    let balanced = spread(true);
    let fixed = spread(false);
    assert!(
        balanced < fixed,
        "balanced spread {balanced:.3} should beat fixed {fixed:.3}"
    );
}

#[test]
fn world_one_matches_multi_world_loss_scale() {
    // Losses are per-sample means, so world=1 and world=4 land in the
    // same range (not equal — different data shards).
    let Some(engine) = engine() else { return };
    let mut r1 = None;
    let mut r4 = None;
    for (world, slot) in [(1usize, &mut r1), (4usize, &mut r4)] {
        let mut opts = base_opts(world, 6);
        opts.collect_gauc = false;
        *slot = Some(Trainer::new(opts, engine.clone()).unwrap().run().unwrap());
    }
    let (a, b) = (r1.unwrap(), r4.unwrap());
    let la = a.steps[0].loss_ctr;
    let lb = b.steps[0].loss_ctr;
    assert!((la - lb).abs() < 0.3, "initial losses far apart: {la} vs {lb}");
}

#[test]
fn grad_accumulation_changes_update_cadence_not_stability() {
    let Some(engine) = engine() else { return };
    let mut opts = base_opts(2, 9);
    opts.train.grad_accum = 3;
    opts.collect_gauc = false;
    let report = Trainer::new(opts, engine).unwrap().run().unwrap();
    assert_eq!(report.steps.len(), 9);
    assert!(report.steps.iter().all(|s| s.loss_ctr.is_finite()));
}
