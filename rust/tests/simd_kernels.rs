//! Bit-identity property tests for the blocked/SIMD sparse kernels.
//!
//! The blocked `gather_rows[_par]` / `scatter_accumulate[_par]` and the
//! blocked `SparseAdam` / `DenseAdam` row updates only regroup
//! independent per-element operations, so every path — fixed-dim
//! specializations, block bodies, scalar tails, and the pool-parallel
//! variants at every threshold setting — must reproduce a longhand
//! scalar reference **bit for bit**. Sweeps cover odd dims,
//! non-block-multiple lengths, empty inverse maps, and thresholds
//! forced both fully on and fully off.

use mtgrboost::embedding::concurrent::ConcurrentDynamicTable;
use mtgrboost::embedding::dedup::{
    add_assign_blocked, gather_rows, gather_rows_par, scatter_accumulate,
    scatter_accumulate_par, Dedup, PAR_ROWS,
};
use mtgrboost::embedding::dynamic_table::{DynamicEmbeddingTable, DynamicTableConfig};
use mtgrboost::embedding::EmbeddingStore;
use mtgrboost::optim::adam::{AdamParams, DenseAdam, SparseAdam, PAR_DENSE};
use mtgrboost::util::pool::WorkerPool;
use mtgrboost::util::rng::Xoshiro256;

/// Longhand scalar gather: `out[i] = rows[inverse[i]]`.
fn gather_ref(rows: &[f32], dim: usize, inverse: &[u32], out: &mut [f32]) {
    for (i, &u) in inverse.iter().enumerate() {
        out[i * dim..(i + 1) * dim]
            .copy_from_slice(&rows[u as usize * dim..(u as usize + 1) * dim]);
    }
}

/// Longhand scalar scatter: `out[inverse[i]] += grads[i]`, occurrence
/// order.
fn scatter_ref(grads: &[f32], dim: usize, inverse: &[u32], out: &mut [f32]) {
    for (i, &u) in inverse.iter().enumerate() {
        for j in 0..dim {
            out[u as usize * dim + j] += grads[i * dim + j];
        }
    }
}

/// Longhand scalar Adam row update: advances `m`/`v` in place for time
/// step `t` and writes the signed delta (the exact historical inline
/// expressions).
#[allow(clippy::too_many_arguments)]
fn adam_row_ref(
    m: &mut [f32],
    v: &mut [f32],
    t: u64,
    g: &[f32],
    scale: f32,
    hp: AdamParams,
    delta: &mut [f32],
) {
    let bc1 = 1.0 - hp.beta1.powi(t as i32);
    let bc2 = 1.0 - hp.beta2.powi(t as i32);
    for j in 0..m.len() {
        let gj = g[j] * scale;
        m[j] = hp.beta1 * m[j] + (1.0 - hp.beta1) * gj;
        v[j] = hp.beta2 * v[j] + (1.0 - hp.beta2) * gj * gj;
        let mhat = m[j] / bc1;
        let vhat = v[j] / bc2;
        delta[j] = -hp.lr * mhat / (vhat.sqrt() + hp.eps);
    }
}

/// Dims crossing every kernel regime: scalar tail only (< 8), exact
/// blocks (8/16/32/64 — the fixed-dim gather specializations), and
/// block + tail mixtures.
const DIMS: &[usize] = &[1, 3, 5, 7, 8, 9, 15, 16, 17, 31, 32, 33, 63, 64, 65];

#[test]
fn add_assign_blocked_matches_naive_for_every_length() {
    let mut rng = Xoshiro256::new(40);
    for len in 0..64usize {
        let src: Vec<f32> = (0..len).map(|_| rng.next_f32() - 0.5).collect();
        let mut naive: Vec<f32> = (0..len).map(|_| rng.next_f32() - 0.5).collect();
        let mut blocked = naive.clone();
        for (d, s) in naive.iter_mut().zip(&src) {
            *d += *s;
        }
        add_assign_blocked(&mut blocked, &src);
        assert_eq!(blocked, naive, "len {len}");
    }
}

#[test]
fn gather_scatter_bit_identical_across_dims_lengths_and_thresholds() {
    // This test owns the PAR_ROWS knob for the whole binary: the other
    // tests here never consult it, so no intra-binary race.
    let mut rng = Xoshiro256::new(41);
    for &dim in DIMS {
        for &n_occ in &[0usize, 1, 7, 57, 300] {
            let ids: Vec<u64> = (0..n_occ).map(|_| rng.gen_range(29)).collect();
            let d = Dedup::of(&ids);
            let rows: Vec<f32> = (0..d.unique.len() * dim)
                .map(|_| rng.next_f32() - 0.5)
                .collect();
            let grads: Vec<f32> = (0..n_occ * dim).map(|_| rng.next_f32() - 0.5).collect();
            let mut exp_ref = vec![0.0f32; n_occ * dim];
            gather_ref(&rows, dim, &d.inverse, &mut exp_ref);
            let mut acc_ref = vec![0.0f32; d.unique.len() * dim];
            scatter_ref(&grads, dim, &d.inverse, &mut acc_ref);

            // Serial blocked kernels.
            let mut exp = vec![0.0f32; n_occ * dim];
            gather_rows(&rows, dim, &d.inverse, &mut exp);
            assert_eq!(exp, exp_ref, "dim {dim} n {n_occ} serial gather");
            let mut acc = vec![0.0f32; d.unique.len() * dim];
            scatter_accumulate(&grads, dim, &d.inverse, &mut acc);
            assert_eq!(acc, acc_ref, "dim {dim} n {n_occ} serial scatter");

            // Parallel variants with the threshold forced fully on
            // (every length engages the pool) and fully off (always
            // the serial fallback), across pool sizes.
            for threshold in [1usize, usize::MAX >> 1] {
                PAR_ROWS.set(threshold);
                for threads in [1usize, 2, 4] {
                    let pool = WorkerPool::new(threads);
                    let mut exp_p = vec![0.0f32; n_occ * dim];
                    gather_rows_par(&rows, dim, &d.inverse, &mut exp_p, Some(&pool));
                    assert_eq!(
                        exp_p, exp_ref,
                        "dim {dim} n {n_occ} thr {threshold} {threads}t gather"
                    );
                    let mut acc_p = vec![0.0f32; d.unique.len() * dim];
                    scatter_accumulate_par(&grads, dim, &d.inverse, &mut acc_p, Some(&pool));
                    assert_eq!(
                        acc_p, acc_ref,
                        "dim {dim} n {n_occ} thr {threshold} {threads}t scatter"
                    );
                }
            }
            PAR_ROWS.set(PAR_ROWS.default_value());
        }
    }
}

#[test]
fn sparse_adam_blocked_rows_match_scalar_reference() {
    let hp = AdamParams::default();
    let scale = 0.25f32;
    for &dim in DIMS {
        let mut rng = Xoshiro256::new(42 + dim as u64);
        let ids: Vec<u64> = (0..23).map(|i| i * 5 + 1).collect(); // unique ascending
        let cfg = DynamicTableConfig::new(dim).with_capacity(512).with_seed(9);

        // Reference state: initial rows snapshotted from an identically
        // seeded table, then advanced with the longhand row update.
        let mut table = DynamicEmbeddingTable::new(cfg.clone());
        let mut buf = vec![0.0f32; dim];
        for &id in &ids {
            table.lookup_or_insert(id, &mut buf);
        }
        let mut rows_ref: Vec<Vec<f32>> = ids
            .iter()
            .map(|&id| {
                let mut b = vec![0.0f32; dim];
                assert!(table.lookup(id, &mut b));
                b
            })
            .collect();
        let mut m_ref = vec![vec![0.0f32; dim]; ids.len()];
        let mut v_ref = vec![vec![0.0f32; dim]; ids.len()];

        let mut opt = SparseAdam::new(dim, hp);
        let mut round_grads: Vec<Vec<f32>> = Vec::new();
        for round in 0..3u64 {
            let grads: Vec<f32> = (0..ids.len() * dim)
                .map(|_| rng.next_f32() - 0.5)
                .collect();
            let mut delta = vec![0.0f32; dim];
            for (i, row) in rows_ref.iter_mut().enumerate() {
                adam_row_ref(
                    &mut m_ref[i],
                    &mut v_ref[i],
                    round + 1,
                    &grads[i * dim..(i + 1) * dim],
                    scale,
                    hp,
                    &mut delta,
                );
                for (r, &dl) in row.iter_mut().zip(&delta) {
                    *r += dl;
                }
            }
            opt.step(&mut table, &ids, &grads, scale);
            round_grads.push(grads);
        }
        for (i, &id) in ids.iter().enumerate() {
            let mut b = vec![0.0f32; dim];
            assert!(table.lookup(id, &mut b));
            assert_eq!(b, rows_ref[i], "dim {dim} id {id} row");
            let st = opt.row_state(id).unwrap();
            assert_eq!(st.m, m_ref[i], "dim {dim} id {id} m");
            assert_eq!(st.v, v_ref[i], "dim {dim} id {id} v");
            assert_eq!(st.t, 3, "dim {dim} id {id} t");
        }

        // step_concurrent replays the same rounds on identically seeded
        // concurrent tables at several pool sizes — rows and optimizer
        // state must land on the same reference bits.
        for threads in [1usize, 2, 4] {
            let conc = ConcurrentDynamicTable::new(cfg.clone(), 8);
            for &id in &ids {
                conc.lookup_or_insert(id, &mut buf);
            }
            let pool = WorkerPool::new(threads);
            let mut o2 = SparseAdam::new(dim, hp);
            for grads in &round_grads {
                o2.step_concurrent(&pool, &conc, &ids, grads, scale);
            }
            for (i, &id) in ids.iter().enumerate() {
                let mut b = vec![0.0f32; dim];
                assert!(conc.lookup(id, &mut b));
                assert_eq!(b, rows_ref[i], "dim {dim} id {id} {threads}t row");
                let st = o2.row_state(id).unwrap();
                assert_eq!(st.m, m_ref[i], "dim {dim} id {id} {threads}t m");
                assert_eq!(st.v, v_ref[i], "dim {dim} id {id} {threads}t v");
            }
        }
    }
}

#[test]
fn dense_adam_blocked_matches_scalar_reference_across_thresholds() {
    // This test owns the PAR_DENSE knob for the whole binary.
    let hp = AdamParams::default();
    let scale = 0.5f32;
    for &n in &[1usize, 7, 8, 33, 10_007] {
        let mut rng = Xoshiro256::new(43);
        let grads: Vec<f32> = (0..n).map(|_| rng.next_f32() - 0.5).collect();
        let init: Vec<f32> = (0..n).map(|_| rng.next_f32() - 0.5).collect();

        // Longhand reference over 3 steps.
        let mut p_ref = init.clone();
        let mut m_ref = vec![0.0f32; n];
        let mut v_ref = vec![0.0f32; n];
        for t in 1..=3i32 {
            let bc1 = 1.0 - hp.beta1.powi(t);
            let bc2 = 1.0 - hp.beta2.powi(t);
            for j in 0..n {
                let g = grads[j] * scale;
                m_ref[j] = hp.beta1 * m_ref[j] + (1.0 - hp.beta1) * g;
                v_ref[j] = hp.beta2 * v_ref[j] + (1.0 - hp.beta2) * g * g;
                let mhat = m_ref[j] / bc1;
                let vhat = v_ref[j] / bc2;
                p_ref[j] -= hp.lr * mhat / (vhat.sqrt() + hp.eps);
            }
        }

        for threshold in [1usize, usize::MAX >> 1] {
            PAR_DENSE.set(threshold);
            for threads in [1usize, 2, 4] {
                let pool = WorkerPool::new(threads);
                let mut p = init.clone();
                let mut o = DenseAdam::new(n, hp);
                for _ in 0..3 {
                    o.step_pooled(&mut p, &grads, scale, Some(&pool));
                }
                assert_eq!(p, p_ref, "n {n} thr {threshold} {threads}t params");
            }
        }
        PAR_DENSE.set(PAR_DENSE.default_value());
    }
}
