//! The trainer shares ONE process-global `WorkerPool` across all of its
//! workers (each gets a deterministic fair-share view), instead of the
//! pre-PR-3 one-pool-per-worker layout that oversubscribed the host at
//! `world × threads` threads.
//!
//! This file is its own test binary — and holds a single `#[test]` — so
//! no other test's pools can race the process-wide live/peak counters.

use mtgrboost::data::generator::GeneratorConfig;
use mtgrboost::runtime::Engine;
use mtgrboost::train::{Trainer, TrainerOptions};
use mtgrboost::util::pool::WorkerPool;

fn opts(world: usize, threads: usize) -> TrainerOptions {
    let mut o = TrainerOptions::new("tiny", world, 4);
    o.generator = GeneratorConfig {
        len_mu: 2.5,
        len_sigma: 0.5,
        min_len: 2,
        max_len: 60,
        num_users: 200,
        num_items: 200,
        ..Default::default()
    };
    o.train.target_tokens = 600;
    o.collect_gauc = false;
    o.threads = threads;
    o
}

#[test]
fn exactly_one_worker_pool_per_training_process() {
    assert_eq!(WorkerPool::live_pool_count(), 0, "no pools before training");

    // world 2 × threads 4: the old layout would have created two
    // 4-thread pools; the global pool keeps the peak at exactly one.
    WorkerPool::reset_peak_pool_count();
    let engine = Engine::reference(7).unwrap();
    let report = Trainer::new(opts(2, 4), engine).unwrap().run().unwrap();
    assert_eq!(report.steps.len(), 4);
    assert_eq!(
        WorkerPool::peak_pool_count(),
        1,
        "training must create exactly one WorkerPool"
    );
    assert_eq!(WorkerPool::live_pool_count(), 0, "pool torn down after run");

    // threads 0 (machine-sized) takes the same single-pool path.
    WorkerPool::reset_peak_pool_count();
    let engine = Engine::reference(7).unwrap();
    let report0 = Trainer::new(opts(2, 0), engine).unwrap().run().unwrap();
    assert_eq!(WorkerPool::peak_pool_count(), 1, "threads=0 still one pool");
    assert_eq!(WorkerPool::live_pool_count(), 0);

    // Same seed, same numerics regardless of pool size — the fair-share
    // views chunk work, never change arithmetic.
    let fp = |r: &mtgrboost::train::TrainReport| {
        (
            r.steps
                .iter()
                .map(|s| (s.loss_ctr.to_bits(), s.loss_ctcvr.to_bits()))
                .collect::<Vec<_>>(),
            r.embedding_checksum,
        )
    };
    assert_eq!(fp(&report), fp(&report0));
}
