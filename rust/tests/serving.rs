//! End-to-end serving suite: the delta-sync consumer side.
//!
//! Pins the hardened chain contract (gapped/torn/aliased snapshot dirs
//! are loud errors, never silent staleness), log-structured compaction
//! (the published base is bit-identical to a full-chain replay —
//! including Adam state — per merge group, across trainer `--threads`
//! values, and whether the chain was folded in one pass or
//! incrementally), crash-mid-compaction recovery, and the
//! [`ServingReplica`] bootstrap/refresh/lookup/forward path whose
//! content checksum must equal the trainer report's
//! `embedding_checksum` bit-for-bit.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use mtgrboost::checkpoint::delta::{
    apply_delta, delta_dir, list_delta_seqs, load_delta_group_dims, load_delta_meta,
    load_delta_precision_policy, load_delta_shard_group, snapshot_rows,
    sparse_delta_group_path, validate_chain,
};
use mtgrboost::checkpoint::{
    load_group_dims, load_precision_policy, load_sparse_shard_group, SparseRow,
};
use mtgrboost::data::generator::GeneratorConfig;
use mtgrboost::embedding::concurrent::ConcurrentDynamicTable;
use mtgrboost::embedding::dynamic_table::DynamicTableConfig;
use mtgrboost::embedding::precision::{PrecisionMode, PrecisionPolicy};
use mtgrboost::online::{AdmissionConfig, OnlineOptions};
use mtgrboost::optim::adam::{AdamParams, SparseAdam};
use mtgrboost::runtime::Engine;
use mtgrboost::serve::compact::latest_base;
use mtgrboost::serve::{
    compact_chain, run_serve, CompactOptions, ReplicaOptions, ServeOptions, ServingReplica,
    TrafficConfig,
};
use mtgrboost::train::{TrainReport, Trainer, TrainerOptions};

const SYNC_INTERVAL: usize = 3;
const INTERVALS: usize = 8;

fn tmp(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("mtgr_serving_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    d
}

/// 8 intervals × 3 steps of online training at toy scale, with
/// admission and TTL expiry both active so the emitted deltas carry
/// upserts AND removals.
fn train(schema: &str, threads: usize, dir: &Path) -> TrainReport {
    train_with(schema, threads, dir, PrecisionMode::Fp32)
}

/// Same workload with a chosen storage precision: `Mixed` keeps rows
/// below a post-bump access count of 3 on the binary16 grid (the
/// threshold is ignored under `Fp32`).
fn train_with(
    schema: &str,
    threads: usize,
    dir: &Path,
    precision: PrecisionMode,
) -> TrainReport {
    let mut o = TrainerOptions::new("tiny", 2, 0);
    o.precision = precision;
    o.hot_threshold = 3;
    o.schema = schema.to_string();
    o.generator = GeneratorConfig {
        len_mu: 2.5,
        len_sigma: 0.5,
        min_len: 2,
        max_len: 60,
        num_users: 400,
        num_items: 250,
        new_user_rate: 0.3,
        new_item_rate: 0.3,
        ..Default::default()
    };
    o.train.target_tokens = 900;
    o.train.lr = 0.01;
    o.shard_capacity = 1024;
    o.collect_gauc = false;
    o.threads = threads;
    let mut online = OnlineOptions::new(SYNC_INTERVAL);
    online.intervals = INTERVALS;
    online.feature_ttl = (3 * SYNC_INTERVAL) as u64;
    online.admission = Some(AdmissionConfig::new(2, 0.05));
    online.day_every = 2;
    online.sync_dir = Some(dir.to_path_buf());
    o.online = Some(online);
    Trainer::new(o, Engine::reference(7).unwrap())
        .unwrap()
        .run()
        .unwrap()
}

/// Full-chain replay of one (rank, group) shard with Adam state — the
/// ground truth a compacted base must reproduce bit-for-bit.
fn replay_group(dir: &Path, rank: usize, group: usize) -> (ConcurrentDynamicTable, SparseAdam) {
    let seqs = list_delta_seqs(dir).unwrap();
    let m0 = load_delta_meta(dir, seqs[0]).unwrap();
    let dim = load_delta_group_dims(dir, &m0).unwrap()[group];
    // Seed/capacity/stripes are irrelevant: rows carry exact bits.
    let table = ConcurrentDynamicTable::new(
        DynamicTableConfig::new(dim).with_capacity(128).with_seed(0xBEEF),
        4,
    );
    let mut opt = SparseAdam::new(dim, AdamParams::default());
    for &seq in &seqs {
        let m = load_delta_meta(dir, seq).unwrap();
        let (rows, removed) = load_delta_shard_group(dir, &m, rank, group).unwrap();
        apply_delta(&table, &mut opt, rows, &removed);
    }
    (table, opt)
}

/// Every file under `dir` as name → bytes (one level, no subdirs).
fn dir_files(dir: &Path) -> BTreeMap<String, Vec<u8>> {
    let mut out = BTreeMap::new();
    for e in std::fs::read_dir(dir).unwrap() {
        let e = e.unwrap();
        out.insert(
            e.file_name().to_string_lossy().into_owned(),
            std::fs::read(e.path()).unwrap(),
        );
    }
    out
}

#[test]
fn compacted_base_matches_full_replay_bit_for_bit_across_threads() {
    let mut base_files: Vec<BTreeMap<String, Vec<u8>>> = Vec::new();
    for threads in [1usize, 4] {
        let dir = tmp(&format!("compact_{threads}t"));
        let report = train("meituan", threads, &dir);

        // Ground truth BEFORE compaction prunes the chain: replay every
        // (rank, group) shard and snapshot its rows (sorted, with Adam).
        let newest = *list_delta_seqs(&dir).unwrap().last().unwrap();
        assert_eq!(newest as usize, INTERVALS, "one delta per interval");
        let meta = load_delta_meta(&dir, newest).unwrap();
        let n_groups = load_delta_group_dims(&dir, &meta).unwrap().len();
        assert_eq!(n_groups, 1, "homogeneous schema folds to one group");
        let mut expected: Vec<Vec<SparseRow>> = Vec::new();
        let mut expected_checksum = 0u64;
        for rank in 0..meta.world {
            let (table, opt) = replay_group(&dir, rank, 0);
            expected_checksum = expected_checksum.wrapping_add(table.content_checksum());
            expected.push(snapshot_rows(&table, &opt));
        }
        assert_eq!(expected_checksum, report.embedding_checksum);
        let dense_bytes =
            std::fs::read(delta_dir(&dir, newest).join("dense.bin")).unwrap();

        let folded = compact_chain(&dir, &CompactOptions::default())
            .unwrap()
            .expect("a chain to fold");
        assert_eq!(folded.prev_base_seq, 0);
        assert_eq!(folded.base_seq, newest);
        assert_eq!(folded.folded_deltas, INTERVALS);
        assert_eq!(folded.step as usize, INTERVALS * SYNC_INTERVAL);
        assert_eq!(folded.checksum, report.embedding_checksum);
        assert!(
            list_delta_seqs(&dir).unwrap().is_empty(),
            "folded deltas must be pruned"
        );

        // The published base IS the replay state, Adam bits included.
        let (bseq, bmeta) = latest_base(&dir).unwrap().expect("a published base");
        assert_eq!(bseq, newest);
        assert_eq!(bmeta.step as usize, INTERVALS * SYNC_INTERVAL);
        let bdir = dir.join(format!("base_{bseq:05}"));
        let mut rows_total = 0usize;
        for (rank, exp) in expected.iter().enumerate() {
            let got =
                load_sparse_shard_group(&bdir, &bmeta, bmeta.world, rank, 0).unwrap();
            assert_eq!(&got, exp, "rank {rank} base rows != full-chain replay");
            rows_total += got.len();
        }
        assert_eq!(rows_total, folded.rows);
        assert_eq!(rows_total, report.table_rows);
        assert_eq!(
            std::fs::read(bdir.join("dense.bin")).unwrap(),
            dense_bytes,
            "dense.bin must be the newest delta's bytes verbatim"
        );

        // A cold replica bootstrapped from the base alone carries the
        // exact trained state, and serves real logits through the model.
        let mut replica = ServingReplica::open(&dir, ReplicaOptions::default()).unwrap();
        assert_eq!(replica.content_checksum(), report.embedding_checksum);
        assert_eq!(replica.resident_rows(), report.table_rows);
        assert_eq!(replica.applied_seq(), newest);
        let ids = replica.live_ids(0);
        assert!(!ids.is_empty());
        let engine = Engine::reference(7).unwrap();
        let tasks = engine.manifest().model("tiny").unwrap().tasks;
        let batch: Vec<&[u64]> = vec![&ids[..4.min(ids.len())], &ids[..1]];
        let logits = replica.forward(&engine, 0, &batch).unwrap();
        assert_eq!(logits.len(), batch.len() * tasks);
        assert!(logits.iter().all(|l| l.is_finite()));

        base_files.push(dir_files(&bdir));
        std::fs::remove_dir_all(&dir).ok();
    }
    assert_eq!(
        base_files[0], base_files[1],
        "compacted base must be byte-identical across trainer --threads {{1,4}}"
    );
}

#[test]
fn incremental_compaction_equals_one_shot_per_merge_group() {
    // Two identical multi-group trainings; fold one chain in a single
    // pass and the other in two (base_4, then base_4 + 5..8): the
    // published base_00008 must be byte-identical either way.
    let dir_a = tmp("oneshot");
    let dir_b = tmp("incremental");
    let report_a = train("meituan-mixed", 1, &dir_a);
    let report_b = train("meituan-mixed", 1, &dir_b);
    assert_eq!(report_a.embedding_checksum, report_b.embedding_checksum);

    let a = compact_chain(&dir_a, &CompactOptions::default())
        .unwrap()
        .expect("chain to fold");
    assert_eq!(a.base_seq as usize, INTERVALS);

    // Stash the back half of b's chain, fold the front, restore, fold
    // the rest on top of the intermediate base.
    let stash = tmp("stash");
    std::fs::create_dir_all(&stash).unwrap();
    for seq in (INTERVALS / 2 + 1)..=INTERVALS {
        let name = format!("delta_{seq:05}");
        std::fs::rename(dir_b.join(&name), stash.join(&name)).unwrap();
    }
    let first = compact_chain(&dir_b, &CompactOptions::default())
        .unwrap()
        .expect("front half to fold");
    assert_eq!(first.base_seq as usize, INTERVALS / 2);
    for seq in (INTERVALS / 2 + 1)..=INTERVALS {
        let name = format!("delta_{seq:05}");
        std::fs::rename(stash.join(&name), dir_b.join(&name)).unwrap();
    }
    let second = compact_chain(&dir_b, &CompactOptions::default())
        .unwrap()
        .expect("back half to fold");
    assert_eq!(second.prev_base_seq as usize, INTERVALS / 2);
    assert_eq!(second.base_seq as usize, INTERVALS);
    assert_eq!(second.checksum, a.checksum);

    let base_name = format!("base_{INTERVALS:05}");
    let files_a = dir_files(&dir_a.join(&base_name));
    let files_b = dir_files(&dir_b.join(&base_name));
    // meituan-mixed forms two merge groups on tiny: group 0 keeps the
    // historical name, group 1 gets the `_g1` suffix — both per rank.
    for rank in 0..2 {
        let g0 = format!("sparse_rank{rank:05}_of2.bin");
        let g1 = format!("sparse_rank{rank:05}_of2_g1.bin");
        assert!(files_a.contains_key(&g0), "missing {g0}");
        assert!(files_a.contains_key(&g1), "missing {g1}");
    }
    assert_eq!(
        files_a, files_b,
        "incremental folding must publish byte-identical bases"
    );

    // Both bases serve the exact trained state across both groups.
    let replica = ServingReplica::open(&dir_b, ReplicaOptions::default()).unwrap();
    assert_eq!(replica.groups(), 2);
    assert_eq!(replica.content_checksum(), report_b.embedding_checksum);
    std::fs::remove_dir_all(&dir_a).ok();
    std::fs::remove_dir_all(&dir_b).ok();
    std::fs::remove_dir_all(&stash).ok();
}

#[test]
fn crash_leftover_stages_are_swept_not_trusted() {
    let dir = tmp("crash");
    let report = train("meituan", 1, &dir);

    // A crash mid-compaction leaves a half-written `.tmp` stage behind.
    // It must never be read as a base, and both compaction and replica
    // bootstrap must sweep it.
    let junk = dir.join("base_00099.tmp");
    std::fs::create_dir_all(&junk).unwrap();
    std::fs::write(junk.join("meta.json"), b"{ half-written garbage").unwrap();
    assert!(
        latest_base(&dir).unwrap().is_none(),
        "a .tmp stage is not a base"
    );

    let folded = compact_chain(&dir, &CompactOptions::default())
        .unwrap()
        .expect("chain still folds");
    assert_eq!(folded.checksum, report.embedding_checksum);
    assert!(!junk.exists(), "compaction must sweep crash leftovers");

    // Plant another leftover after the base exists: replica bootstrap
    // sweeps it and serves from the real base.
    std::fs::create_dir_all(&junk).unwrap();
    std::fs::write(junk.join("garbage.bin"), [0u8; 16]).unwrap();
    let replica = ServingReplica::open(&dir, ReplicaOptions::default()).unwrap();
    assert_eq!(replica.content_checksum(), report.embedding_checksum);
    assert!(!junk.exists(), "replica bootstrap must sweep crash leftovers");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn gapped_or_malformed_chains_are_rejected_loudly() {
    let dir = tmp("reject");
    let report = train("meituan", 1, &dir);
    let opts = ReplicaOptions::default();

    // (a) Gap: hide a middle delta. Bootstrap must refuse to replay
    // across the hole rather than serve silently stale rows.
    let hole = delta_dir(&dir, 3);
    let stashed = dir.join("stashed_delta");
    std::fs::rename(&hole, &stashed).unwrap();
    let err = ServingReplica::open(&dir, opts.clone()).unwrap_err().to_string();
    assert!(err.contains("gap"), "gap must be named: {err}");
    std::fs::rename(&stashed, &hole).unwrap();

    // (b) Torn snapshot: a truncated meta.json marks an interrupted
    // write; the whole dir is rejected, not skipped.
    let meta_path = delta_dir(&dir, 5).join("meta.json");
    let meta_bytes = std::fs::read(&meta_path).unwrap();
    std::fs::write(&meta_path, b"{}").unwrap();
    let err = ServingReplica::open(&dir, opts.clone()).unwrap_err().to_string();
    assert!(err.contains("torn"), "torn dirs must be named: {err}");
    std::fs::write(&meta_path, &meta_bytes).unwrap();

    // (c) Aliased spelling: `delta_7` would shadow `delta_00007`;
    // ambiguous names are an error, never a silent alias.
    let alias = dir.join("delta_7");
    std::fs::create_dir_all(&alias).unwrap();
    let err = ServingReplica::open(&dir, opts.clone()).unwrap_err().to_string();
    assert!(err.contains("alias"), "aliases must be rejected: {err}");
    std::fs::remove_dir_all(&alias).unwrap();

    // (d) Swapped dirs: the name set stays contiguous but delta_00003
    // now holds delta_00004's meta — the seq↔dirname check catches it.
    let d3 = delta_dir(&dir, 3);
    let d4 = delta_dir(&dir, 4);
    let swap = dir.join("swap_tmp");
    std::fs::rename(&d3, &swap).unwrap();
    std::fs::rename(&d4, &d3).unwrap();
    std::fs::rename(&swap, &d4).unwrap();
    let err = ServingReplica::open(&dir, opts.clone()).unwrap_err().to_string();
    assert!(
        err.contains("renamed or torn"),
        "seq mismatch must be rejected: {err}"
    );
    std::fs::rename(&d3, &swap).unwrap();
    std::fs::rename(&d4, &d3).unwrap();
    std::fs::rename(&swap, &d4).unwrap();

    // Restored chain is whole again and validate_chain agrees.
    assert_eq!(validate_chain(&dir, 0, 0).unwrap().len(), INTERVALS);
    let replica = ServingReplica::open(&dir, opts).unwrap();
    assert_eq!(replica.content_checksum(), report.embedding_checksum);

    // (e) An empty sync dir is "nothing to serve", not an empty replica.
    let empty = tmp("empty");
    std::fs::create_dir_all(&empty).unwrap();
    let err = ServingReplica::open(&empty, ReplicaOptions::default())
        .unwrap_err()
        .to_string();
    assert!(err.contains("nothing to serve"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::remove_dir_all(&empty).ok();
}

#[test]
fn refresh_consumes_newly_published_deltas() {
    let dir = tmp("refresh");
    let report = train("meituan", 1, &dir);

    // Hide the back half of the chain: the replica boots at seq 4, then
    // "the trainer publishes" (restore) and refresh folds the rest in.
    let stash = tmp("refresh_stash");
    std::fs::create_dir_all(&stash).unwrap();
    for seq in (INTERVALS / 2 + 1)..=INTERVALS {
        let name = format!("delta_{seq:05}");
        std::fs::rename(dir.join(&name), stash.join(&name)).unwrap();
    }
    let mut replica = ServingReplica::open(&dir, ReplicaOptions::default()).unwrap();
    assert_eq!(replica.applied_seq() as usize, INTERVALS / 2);
    // Warm the cache with every live id so refresh invalidation is
    // actually exercised (the later deltas touch many of these rows).
    let warm_ids = replica.live_ids(0);
    let dim = replica.group_dim(0);
    let mut buf = vec![0.0f32; dim];
    for &id in &warm_ids {
        replica.lookup(0, id, &mut buf);
        replica.lookup(0, id, &mut buf); // second hit comes from cache
    }
    assert!(replica.stats().cache_hits > 0);

    for seq in (INTERVALS / 2 + 1)..=INTERVALS {
        let name = format!("delta_{seq:05}");
        std::fs::rename(stash.join(&name), dir.join(&name)).unwrap();
    }
    assert_eq!(replica.refresh().unwrap(), INTERVALS / 2);
    assert_eq!(replica.applied_seq() as usize, INTERVALS);
    assert_eq!(replica.content_checksum(), report.embedding_checksum);
    assert!(
        replica.stats().cache_invalidations > 0,
        "refresh must invalidate delta-touched cached ids"
    );
    // Served rows reflect the refreshed state: every cached id re-read
    // after refresh matches the table's row bits.
    for &id in warm_ids.iter().take(64) {
        if replica.lookup(0, id, &mut buf) {
            let mut again = vec![0.0f32; dim];
            assert!(replica.lookup(0, id, &mut again));
            assert_eq!(buf, again, "cache and table disagree for id {id}");
        }
    }
    assert_eq!(replica.refresh().unwrap(), 0, "nothing new to fold");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::remove_dir_all(&stash).ok();
}

#[test]
fn run_serve_end_to_end_over_a_live_sync_dir() {
    let dir = tmp("run_serve");
    let report = train("meituan", 1, &dir);
    let engine = Engine::reference(7).unwrap();
    let opts = ServeOptions {
        requests: 64,
        micro_batch: 8,
        refresh_every: 32,
        compact_every: 48,
        traffic: TrafficConfig {
            users: 5_000,
            qps: 1000.0,
            day_seconds: 0.5,
            ids_per_request: 16,
            ..TrafficConfig::default()
        },
        ..ServeOptions::default()
    };
    let serve = run_serve(&dir, &engine, &opts).unwrap();
    assert_eq!(serve.requests, 64);
    assert_eq!(serve.micro_batches, 8);
    assert_eq!(serve.stats.lookups, 64 * 16);
    assert_eq!(
        serve.stats.resident + serve.stats.missing,
        serve.stats.lookups
    );
    assert!(serve.stats.missing > 0, "miss traffic must exercise cold ids");
    assert!(serve.cache_hit_rate > 0.0, "hot ids must hit the cache");
    assert!(serve.latency_ms.p50 > 0.0 && serve.latency_ms.p50.is_finite());
    assert!(serve.latency_ms.p99 >= serve.latency_ms.p50);
    assert!(serve.achieved_qps > 0.0);
    assert!(serve.compactions >= 1, "compact_every must trigger");
    assert_eq!(serve.applied_seq as usize, INTERVALS);
    assert_eq!(serve.embedding_checksum, report.embedding_checksum);
    // The compaction pass published a base and pruned the chain.
    assert!(list_delta_seqs(&dir).unwrap().is_empty());
    assert!(latest_base(&dir).unwrap().is_some());
    std::fs::remove_dir_all(&dir).ok();
}

/// Flat-copy one snapshot dir (delta dirs hold no subdirs).
fn copy_delta_dir(src: &Path, dst: &Path) {
    std::fs::create_dir_all(dst).unwrap();
    for e in std::fs::read_dir(src).unwrap() {
        let e = e.unwrap();
        std::fs::copy(e.path(), dst.join(e.file_name())).unwrap();
    }
}

#[test]
fn failed_refresh_keeps_serving_last_good_state() {
    let dir = tmp("degrade");
    train("meituan", 1, &dir);
    let stash = tmp("degrade_stash");
    std::fs::create_dir_all(&stash).unwrap();
    // Hold back deltas 5..=8 so the replica bootstraps on 1..=4 and the
    // "trainer" can publish broken continuations.
    for seq in 5..=INTERVALS as u64 {
        let src = delta_dir(&dir, seq);
        std::fs::rename(&src, stash.join(src.file_name().unwrap())).unwrap();
    }
    let mut replica = ServingReplica::open(&dir, ReplicaOptions::default()).unwrap();
    let good_seq = replica.applied_seq();
    let good_sum = replica.content_checksum();
    let probe = replica.live_ids(0)[0];
    let world = replica.world();

    // Gapped chain: delta 6 appears without delta 5. The refresh must
    // fail loudly — but the replica keeps serving its pre-refresh state
    // and the failure is visible in the counters.
    let d6 = delta_dir(&dir, 6);
    std::fs::rename(stash.join(d6.file_name().unwrap()), &d6).unwrap();
    assert!(replica.refresh().is_err(), "gap must not fold in");
    let stats = replica.stats();
    assert_eq!(stats.refresh_failures, 1);
    assert!(
        stats.last_refresh_error.is_some(),
        "operators polling stats see the failure reason"
    );
    assert_eq!(replica.applied_seq(), good_seq, "state not advanced");
    assert_eq!(replica.content_checksum(), good_sum, "state untouched");
    let mut out = vec![0.0; replica.group_dim(0)];
    assert!(replica.lookup(0, probe, &mut out), "still serving");

    // Torn mid-chain shard: delta 5 arrives but one of its row files is
    // truncated mid-write. The chain now LOOKS contiguous — only the
    // staged CRC-checked loads catch it, and because staging precedes
    // every install, deltas 5 AND 6 both stay out.
    let d5 = delta_dir(&dir, 5);
    copy_delta_dir(&stash.join(d5.file_name().unwrap()), &d5);
    let shard = sparse_delta_group_path(&dir, 5, 0, world, 0);
    let len = std::fs::metadata(&shard).unwrap().len();
    let f = std::fs::OpenOptions::new().write(true).open(&shard).unwrap();
    f.set_len(len / 2).unwrap();
    drop(f);
    assert!(replica.refresh().is_err(), "torn shard must not fold in");
    assert_eq!(replica.stats().refresh_failures, 2);
    assert_eq!(replica.applied_seq(), good_seq);
    assert_eq!(replica.content_checksum(), good_sum, "no half-applied refresh");

    // Repair delta 5: the very next refresh folds 5 and 6 in — the
    // degraded window cost availability of fresh rows, never serving.
    copy_delta_dir(&stash.join(d5.file_name().unwrap()), &d5);
    assert_eq!(replica.refresh().unwrap(), 2);
    assert_eq!(replica.applied_seq(), 6);
    assert_eq!(replica.stats().refresh_failures, 2, "failure count is history");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::remove_dir_all(&stash).ok();
}

#[test]
fn mixed_precision_chain_round_trips_cold_rows_on_the_f16_grid() {
    use mtgrboost::util::f16::quantize_f16;
    use mtgrboost::util::json::Json;

    let dir = tmp("mixed_prec");
    let report = train_with("meituan-mixed", 1, &dir, PrecisionMode::Mixed);
    assert_eq!(report.precision, "mixed");
    assert!(
        report.hot_rows > 0 && report.cold_rows > 0,
        "both classes must populate: {} hot / {} cold",
        report.hot_rows,
        report.cold_rows
    );

    // Every delta in the chain records the policy it was trained under.
    for &seq in &list_delta_seqs(&dir).unwrap() {
        assert_eq!(
            load_delta_precision_policy(&dir, seq).unwrap(),
            PrecisionPolicy::mixed(3),
            "delta {seq} lost the precision metadata"
        );
    }

    // A replica serves the mixed chain bit-exactly: cold rows arrive
    // already on the f16 grid, installs copy bits verbatim, so the
    // content checksum matches the trainer's with no dequantization.
    let replica = ServingReplica::open(&dir, ReplicaOptions::default()).unwrap();
    assert_eq!(replica.precision(), PrecisionPolicy::mixed(3));
    assert_eq!(replica.content_checksum(), report.embedding_checksum);
    assert_eq!(replica.resident_rows(), report.table_rows);
    drop(replica);

    // A trainer restarted with different --precision/--hot-threshold
    // flags mid-chain must be refused loudly, never served: doctor one
    // delta's recorded threshold and bootstrap again.
    let mid = delta_dir(&dir, (INTERVALS / 2) as u64).join("meta.json");
    let original = std::fs::read_to_string(&mid).unwrap();
    let mut j = Json::parse(&original).unwrap();
    j.set("hot_threshold", 9usize.into());
    std::fs::write(&mid, j.pretty()).unwrap();
    let err = ServingReplica::open(&dir, ReplicaOptions::default())
        .unwrap_err()
        .to_string();
    assert!(
        err.contains("precision policy"),
        "mid-chain flag flips must be named: {err}"
    );
    std::fs::write(&mid, &original).unwrap();

    // Compaction folds the chain, carries the policy into the published
    // base, and the base's rows partition onto their grids: at least
    // `cold_rows` sit exactly on binary16, and the hot rows keep full
    // FP32 state (so not everything is on the grid).
    let folded = compact_chain(&dir, &CompactOptions::default())
        .unwrap()
        .expect("a chain to fold");
    assert_eq!(folded.checksum, report.embedding_checksum);
    let (bseq, bmeta) = latest_base(&dir).unwrap().expect("a published base");
    let bdir = dir.join(format!("base_{bseq:05}"));
    assert_eq!(
        load_precision_policy(&bdir).unwrap(),
        PrecisionPolicy::mixed(3),
        "the base must survive pruning of the deltas that carried the policy"
    );
    let gdims = load_group_dims(&bdir, &bmeta).unwrap();
    assert_eq!(gdims.len(), 2, "meituan-mixed folds to two merge groups");
    let (mut total, mut on_grid) = (0usize, 0usize);
    for rank in 0..bmeta.world {
        for g in 0..gdims.len() {
            for row in load_sparse_shard_group(&bdir, &bmeta, bmeta.world, rank, g).unwrap() {
                total += 1;
                if row
                    .row
                    .iter()
                    .all(|&x| x.to_bits() == quantize_f16(x).to_bits())
                {
                    on_grid += 1;
                }
            }
        }
    }
    assert_eq!(total, report.table_rows);
    assert!(
        on_grid as u64 >= report.cold_rows,
        "every cold row must sit on the f16 grid: {on_grid} on-grid vs {} cold",
        report.cold_rows
    );
    assert!(
        on_grid < total,
        "hot rows must keep off-grid FP32 state: {on_grid}/{total} on-grid"
    );

    // The base alone (deltas pruned) still bootstraps the exact state.
    assert!(list_delta_seqs(&dir).unwrap().is_empty());
    let recovered = ServingReplica::open(&dir, ReplicaOptions::default()).unwrap();
    assert_eq!(recovered.precision(), PrecisionPolicy::mixed(3));
    assert_eq!(recovered.content_checksum(), report.embedding_checksum);
    assert_eq!(recovered.resident_rows(), report.table_rows);
    std::fs::remove_dir_all(&dir).ok();
}
