//! Property and stress tests for the concurrent lock-striped embedding
//! table: random interleavings of insert/remove/evict preserve row
//! contents, load-factor bounds hold per stripe, live IDs are never
//! lost, concurrent readers observe internally consistent rows, and a
//! multi-threaded shard-stress run produces results identical to the
//! single-threaded [`DynamicEmbeddingTable`].

use std::collections::HashMap;
use std::sync::Arc;

use mtgrboost::embedding::concurrent::ConcurrentDynamicTable;
use mtgrboost::embedding::dynamic_table::{DynamicEmbeddingTable, DynamicTableConfig};
use mtgrboost::embedding::EmbeddingStore;
use mtgrboost::util::rng::Xoshiro256;

/// Property: under any interleaving of insert / lookup / delta / remove
/// the striped table behaves exactly like a HashMap, keeps every live
/// id reachable, and every stripe's load factor stays below the
/// expansion threshold.
#[test]
fn prop_interleavings_match_hashmap_and_respect_bounds() {
    for case in 0..20u64 {
        let mut rng = Xoshiro256::new(9000 + case);
        let dim = rng.range_usize(1, 7);
        let stripes = 1usize << rng.range_usize(0, 4);
        let table = ConcurrentDynamicTable::new(
            DynamicTableConfig::new(dim)
                .with_capacity(1 << rng.range_usize(5, 8))
                .with_seed(case),
            stripes,
        );
        let mut reference: HashMap<u64, Vec<f32>> = HashMap::new();
        let mut buf = vec![0.0f32; dim];
        for step in 0..2500 {
            let id = rng.gen_range(400);
            match rng.gen_range(12) {
                0..=6 => {
                    let existed = table.lookup_or_insert(id, &mut buf);
                    assert_eq!(existed, reference.contains_key(&id), "case {case} step {step}");
                    reference.entry(id).or_insert_with(|| buf.clone());
                    assert_eq!(&buf, reference.get(&id).unwrap(), "case {case}");
                }
                7..=8 => {
                    let delta: Vec<f32> = (0..dim).map(|_| rng.next_f32() - 0.5).collect();
                    let ok = table.apply_delta(id, &delta);
                    assert_eq!(ok, reference.contains_key(&id));
                    if let Some(row) = reference.get_mut(&id) {
                        for (r, d) in row.iter_mut().zip(&delta) {
                            *r += d;
                        }
                    }
                }
                9..=10 => {
                    assert_eq!(table.remove(id), reference.remove(&id).is_some());
                }
                _ => {
                    let found = table.lookup(id, &mut buf);
                    assert_eq!(found, reference.contains_key(&id));
                }
            }
            assert_eq!(table.len(), reference.len(), "case {case} step {step}");
        }
        assert!(
            table.max_load_factor() <= 0.76,
            "case {case}: load factor {}",
            table.max_load_factor()
        );
        // No live id lost; contents intact bit-for-bit.
        let mut live = table.live_ids();
        live.sort_unstable();
        let mut expect: Vec<u64> = reference.keys().copied().collect();
        expect.sort_unstable();
        assert_eq!(live, expect, "case {case}");
        for (id, row) in &reference {
            assert_eq!(
                table.row(*id).as_deref(),
                Some(row.as_slice()),
                "case {case} id {id}"
            );
        }
    }
}

/// Property: with a row budget, random insert/evict interleavings keep
/// the table bounded and never corrupt surviving rows.
#[test]
fn prop_eviction_keeps_table_bounded_and_rows_intact() {
    for case in 0..8u64 {
        let mut rng = Xoshiro256::new(700 + case);
        let stripes = 4usize;
        let budget = 96usize;
        let table = ConcurrentDynamicTable::new(
            DynamicTableConfig::new(4)
                .with_capacity(512)
                .with_seed(case)
                .with_max_rows(budget),
            stripes,
        );
        let mut buf = vec![0.0f32; 4];
        for _ in 0..5000 {
            let id = rng.gen_range(3000);
            table.lookup_or_insert(id, &mut buf);
            if rng.bernoulli(0.05) {
                table.evict_one();
            }
        }
        // Per-stripe budget of ceil(96/4) ⇒ at most budget + stripes rows.
        assert!(
            table.len() <= budget + stripes,
            "case {case}: len {}",
            table.len()
        );
        assert!(table.stats().evictions > 0);
        // Surviving rows still match their deterministic re-derivation:
        // a row never updated equals a fresh insert in a same-seed table.
        let fresh = ConcurrentDynamicTable::new(
            DynamicTableConfig::new(4).with_capacity(512).with_seed(case),
            stripes,
        );
        for id in table.live_ids() {
            let mut expect = vec![0.0f32; 4];
            fresh.lookup_or_insert(id, &mut expect);
            assert_eq!(table.row(id).unwrap(), expect, "case {case} id {id}");
        }
    }
}

/// Concurrent readers during writes always observe internally
/// consistent rows. Rows are pinned to "all dims equal" (zeroed after
/// insert, then incremented by whole-row +1.0 deltas); a torn read
/// would surface as a row whose elements disagree.
#[test]
fn concurrent_readers_see_consistent_rows_during_writes() {
    const DIM: usize = 8;
    const IDS: u64 = 128;
    const WRITES_PER_THREAD: usize = 400;
    const WRITERS: u64 = 4;
    let table = Arc::new(ConcurrentDynamicTable::new(
        DynamicTableConfig::new(DIM).with_capacity(1024).with_seed(42),
        8,
    ));
    // Zero every row exactly (subtract its own init), establishing the
    // all-dims-equal invariant writers maintain.
    let mut buf = vec![0.0f32; DIM];
    for id in 0..IDS {
        table.lookup_or_insert(id, &mut buf);
        let neg: Vec<f32> = buf.iter().map(|x| -x).collect();
        assert!(table.apply_delta(id, &neg));
    }

    let mut joins = Vec::new();
    for w in 0..WRITERS {
        let table = Arc::clone(&table);
        joins.push(std::thread::spawn(move || {
            let mut rng = Xoshiro256::new(100 + w);
            let inc = vec![1.0f32; DIM];
            for _ in 0..WRITES_PER_THREAD {
                let id = rng.gen_range(IDS);
                assert!(table.apply_delta(id, &inc));
            }
        }));
    }
    for r in 0..4u64 {
        let table = Arc::clone(&table);
        joins.push(std::thread::spawn(move || {
            let mut rng = Xoshiro256::new(200 + r);
            let mut out = vec![0.0f32; DIM];
            let max = (WRITERS as usize * WRITES_PER_THREAD) as f32;
            for _ in 0..4000 {
                let id = rng.gen_range(IDS);
                assert!(table.lookup(id, &mut out));
                let first = out[0];
                assert!(
                    out.iter().all(|&x| x == first),
                    "torn row for id {id}: {out:?}"
                );
                assert!(first >= 0.0 && first <= max && first.fract() == 0.0);
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    // Total increments conserved: Σ row values = DIM · total writes.
    let mut total = 0.0f64;
    let mut out = vec![0.0f32; DIM];
    for id in 0..IDS {
        assert!(table.lookup(id, &mut out));
        total += out[0] as f64;
    }
    assert_eq!(total as usize, WRITERS as usize * WRITES_PER_THREAD);
}

/// The acceptance stress: many threads hammer one shard with parallel
/// lookups and integer-valued updates; the result must be identical to
/// a single-threaded [`DynamicEmbeddingTable`] replaying the same op
/// multiset. Integer-valued deltas make float accumulation
/// order-independent, so equality is exact.
#[test]
fn stress_parallel_shard_matches_single_threaded_table() {
    const DIM: usize = 16;
    const IDS: u64 = 500;
    const THREADS: u64 = 8;
    let cfg = || DynamicTableConfig::new(DIM).with_capacity(2048).with_seed(77);
    let conc = Arc::new(ConcurrentDynamicTable::new(cfg(), 8));

    let mut joins = Vec::new();
    for t in 0..THREADS {
        let conc = Arc::clone(&conc);
        joins.push(std::thread::spawn(move || {
            let mut buf = vec![0.0f32; DIM];
            // Each thread owns ids ≡ t (mod THREADS) for updates but
            // reads everything, so stripes see mixed reader/writer
            // traffic (the stage-2 server pattern).
            let mut rng = Xoshiro256::new(t);
            for id in (t..IDS).step_by(THREADS as usize) {
                conc.lookup_or_insert(id, &mut buf);
                let k = 1 + (id % 5) as usize;
                let inc = vec![1.0f32; DIM];
                for _ in 0..k {
                    assert!(conc.apply_delta(id, &inc));
                }
            }
            for _ in 0..1000 {
                let id = rng.gen_range(IDS);
                let _ = conc.lookup(id, &mut buf);
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }

    // Single-threaded replay of the same multiset of operations.
    let mut single = DynamicEmbeddingTable::new(cfg());
    let mut buf = vec![0.0f32; DIM];
    for id in 0..IDS {
        single.lookup_or_insert(id, &mut buf);
        let k = 1 + (id % 5) as usize;
        let inc = vec![1.0f32; DIM];
        for _ in 0..k {
            assert!(single.apply_delta(id, &inc));
        }
    }

    assert_eq!(conc.len(), single.len());
    let mut a = vec![0.0f32; DIM];
    for id in 0..IDS {
        assert!(conc.lookup(id, &mut a), "id {id} lost");
        let mut b = vec![0.0f32; DIM];
        assert!(single.lookup(id, &mut b));
        assert_eq!(a, b, "id {id}: parallel result differs from single-threaded");
    }
    assert_eq!(conc.stats().inserts, single.stats.inserts);
}
