//! Integration: the sparse-embedding subsystem composed end to end —
//! schema → merge plan → sharded dynamic tables → dedup → gradients —
//! without the PJRT runtime (pure L3).

use std::sync::Arc;
use std::thread;

use mtgrboost::collective::comm::{CommGroup, CommHandle};
use mtgrboost::data::generator::{GeneratorConfig, WorkloadGenerator};
use mtgrboost::data::schema::Schema;
use mtgrboost::embedding::dedup::DedupStrategy;
use mtgrboost::embedding::dynamic_table::{DynamicEmbeddingTable, DynamicTableConfig};
use mtgrboost::embedding::merge::{HashTableCollection, MergePlan};
use mtgrboost::embedding::sharded::ShardedEmbedding;
use mtgrboost::embedding::EmbeddingStore;
use mtgrboost::optim::adam::{AdamParams, SparseAdam};
use mtgrboost::util::rng::Xoshiro256;

const DIM: usize = 8;

fn run_world<T: Send + 'static>(
    world: usize,
    f: impl Fn(usize, &mut CommHandle) -> T + Send + Sync + 'static,
) -> Vec<T> {
    let f = Arc::new(f);
    CommGroup::new(world)
        .into_iter()
        .enumerate()
        .map(|(rank, mut h)| {
            let f = Arc::clone(&f);
            thread::spawn(move || f(rank, &mut h))
        })
        .collect::<Vec<_>>()
        .into_iter()
        .map(|j| j.join().unwrap())
        .collect()
}

#[test]
fn workload_through_merged_tables() {
    // Generate real sequences, route every feature through the merge
    // plan into a HashTableCollection, and check row accounting.
    let schema = Schema::meituan_like(DIM, 1);
    let mut coll = HashTableCollection::new(
        &schema.all_features(),
        &DynamicTableConfig::new(DIM).with_capacity(1024),
    );
    // 7 logical tables merged into 1 lookup op (all share dim).
    assert_eq!(coll.plan.ops_before, 7);
    assert_eq!(coll.num_lookup_ops(), 1);

    let mut gen = WorkloadGenerator::new(GeneratorConfig {
        len_mu: 3.0,
        ..Default::default()
    });
    let mut buf = vec![0.0f32; DIM];
    let mut occurrences = 0usize;
    for _ in 0..50 {
        let seq = gen.next_sequence(&schema);
        for (fi, id) in seq.flat_ids(&schema) {
            let name = &schema.all_features()[fi].name.clone();
            coll.lookup_or_insert(name, id, &mut buf);
            occurrences += 1;
        }
    }
    assert!(occurrences > 1000);
    let rows = coll.total_rows();
    assert!(rows > 100 && rows < occurrences, "dedup inherent in storage");
    assert!(coll.memory_bytes() > rows * DIM * 4);
}

#[test]
fn distributed_lookup_update_lookup_cycle() {
    // Lookup, apply sparse Adam on the owning shards, lookup again —
    // every occurrence of an id must see the updated row, across ranks.
    let out = run_world(4, |_rank, comm| {
        let table = DynamicEmbeddingTable::new(
            DynamicTableConfig::new(DIM).with_capacity(256).with_seed(3),
        );
        let mut se = ShardedEmbedding::new(table, DedupStrategy::TwoStage);
        let mut opt = SparseAdam::new(DIM, AdamParams::default());

        let ids = vec![11u64, 22, 11, 33];
        let before = se.lookup(comm, &ids, true);
        // Everyone pushes gradient 1.0 for all occurrences.
        let grads = vec![1.0f32; ids.len() * DIM];
        let (lids, lgrads) = se.backward(comm, &ids, &grads);
        opt.step(se.table_mut(), &lids, &lgrads, 1.0);
        let after = se.lookup(comm, &ids, true);
        (before, after)
    });
    for (before, after) in out {
        // Adam's first step moves each coordinate by ≈ -lr.
        for (b, a) in before.iter().zip(after.iter()) {
            let delta = a - b;
            assert!(
                (delta + 1e-3).abs() < 2e-4,
                "expected ≈ -lr update, got {delta}"
            );
        }
    }
}

#[test]
fn duplicate_heavy_batches_consistent_under_all_strategies() {
    // A pathological batch (one id repeated 1000x) must produce
    // identical results and identical aggregated gradients under every
    // dedup strategy.
    for strategy in [
        DedupStrategy::None,
        DedupStrategy::CommUnique,
        DedupStrategy::LookupUnique,
        DedupStrategy::TwoStage,
    ] {
        let out = run_world(2, move |rank, comm| {
            let table = DynamicEmbeddingTable::new(
                DynamicTableConfig::new(DIM).with_capacity(256).with_seed(5),
            );
            let mut se = ShardedEmbedding::new(table, strategy);
            let ids = vec![777u64; 1000];
            let rows = se.lookup(comm, &ids, true);
            // All occurrences identical.
            for i in 1..1000 {
                assert_eq!(rows[..DIM], rows[i * DIM..(i + 1) * DIM]);
            }
            let grads = vec![0.5f32; ids.len() * DIM];
            let (lids, lgrads) = se.backward(comm, &ids, &grads);
            if lids.is_empty() {
                0.0
            } else {
                assert_eq!(lids, vec![777]);
                let _ = rank;
                lgrads[0]
            }
        });
        // Exactly one rank owns id 777; its aggregated gradient is
        // 1000 occurrences × 2 ranks × 0.5.
        let owners: Vec<f32> = out.into_iter().filter(|&g| g != 0.0).collect();
        assert_eq!(owners, vec![1000.0], "strategy {strategy:?}");
    }
}

#[test]
fn eviction_under_churn_keeps_table_bounded() {
    let mut table = DynamicEmbeddingTable::new(
        DynamicTableConfig::new(DIM)
            .with_capacity(512)
            .with_max_rows(300)
            .with_seed(8),
    );
    let mut rng = Xoshiro256::new(1);
    let mut buf = vec![0.0f32; DIM];
    for step in 0..20_000 {
        let id = rng.gen_range(5_000);
        table.lookup_or_insert(id, &mut buf);
        if step % 1000 == 0 {
            assert!(table.len() <= 301, "budget violated: {}", table.len());
            assert!(table.load_factor() <= 0.76);
        }
    }
    assert!(table.stats.evictions > 0);
    // Table still functionally correct after heavy churn.
    table.lookup_or_insert(999_999, &mut buf);
    let mut out = vec![0.0f32; DIM];
    assert!(table.lookup(999_999, &mut out));
    assert_eq!(buf, out);
}

#[test]
fn merge_plan_global_ids_are_stable_across_processes() {
    // Two independently built plans over the same schema must agree on
    // every global id (required for checkpoint portability).
    let schema = Schema::meituan_like(DIM, 1);
    let p1 = MergePlan::build(&schema.all_features());
    let p2 = MergePlan::build(&schema.all_features());
    let mut rng = Xoshiro256::new(2);
    for _ in 0..1000 {
        let f = &schema.all_features()[rng.range_usize(0, 7)].name.clone();
        let id = rng.next_u64() >> 4;
        assert_eq!(p1.global_id(f, id), p2.global_id(f, id));
    }
}
