//! Integration: the PJRT runtime executes the AOT artifacts end to end.
//!
//! Requires `make artifacts` (skipped otherwise). These tests prove the
//! three-layer composition: the Pallas kernel (L1) inside the JAX model
//! (L2), lowered to HLO text, loaded and executed from Rust (L3).

use mtgrboost::runtime::{ArtifactKind, Engine, Manifest, Tensor};
use mtgrboost::util::rng::Xoshiro256;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => {
                eprintln!("skipping: run `make artifacts` first");
                return;
            }
        }
    };
}

fn make_inputs(
    b: usize,
    l: usize,
    d: usize,
    tasks: usize,
    seed: u64,
) -> (Vec<f32>, Vec<i32>, Vec<f32>) {
    let mut rng = Xoshiro256::new(seed);
    let emb: Vec<f32> = (0..b * l * d)
        .map(|_| rng.normal(0.0, 0.1) as f32)
        .collect();
    let lengths: Vec<i32> = (0..b)
        .map(|i| {
            if i == b - 1 {
                0 // one padded sample
            } else {
                rng.range_usize(1, l + 1) as i32
            }
        })
        .collect();
    let labels: Vec<f32> = (0..b * tasks)
        .map(|_| rng.gen_range(2) as f32)
        .collect();
    (emb, lengths, labels)
}

#[test]
fn train_step_runs_and_outputs_are_sane() {
    let dir = require_artifacts!();
    let engine = Engine::start(&dir).unwrap();
    let arts = engine.manifest().model("tiny").unwrap().clone();
    let params = arts.load_params(&dir).unwrap();
    let bucket = arts.buckets[0].clone();
    let (b, l, d) = (bucket.batch, bucket.len, arts.emb_dim);
    let (emb, lengths, labels) = make_inputs(b, l, d, arts.tasks, 42);

    let out = engine
        .train_step(
            "tiny",
            (b, l),
            &params,
            Tensor::f32(&[b, l, d], emb),
            lengths.clone(),
            labels,
        )
        .unwrap();

    assert_eq!(out.loss_sums.len(), arts.tasks);
    assert_eq!(out.grads.len(), arts.param_count);
    assert_eq!(out.emb_grad.len(), b * l * d);
    assert_eq!(out.logits.len(), b * arts.tasks);
    let valid = lengths.iter().filter(|&&x| x > 0).count() as f32;
    assert_eq!(out.n_valid, valid);
    assert!(out.loss_sums.iter().all(|x| x.is_finite() && *x > 0.0));
    assert!(out.grads.iter().all(|x| x.is_finite()));
    let gnorm: f32 = out.grads.iter().map(|g| g * g).sum::<f32>().sqrt();
    assert!(gnorm > 1e-3, "gradient must be nonzero, got {gnorm}");

    // Padded sample (last) must have exactly zero embedding gradient.
    let pad = &out.emb_grad[(b - 1) * l * d..];
    assert!(pad.iter().all(|&x| x == 0.0), "padded emb grad leaks");
}

#[test]
fn forward_matches_train_logits() {
    let dir = require_artifacts!();
    let engine = Engine::start(&dir).unwrap();
    let arts = engine.manifest().model("tiny").unwrap().clone();
    let params = arts.load_params(&dir).unwrap();
    let bucket = arts.buckets[0].clone();
    let (b, l, d) = (bucket.batch, bucket.len, arts.emb_dim);
    let (emb, lengths, labels) = make_inputs(b, l, d, arts.tasks, 7);

    let train = engine
        .train_step(
            "tiny",
            (b, l),
            &params,
            Tensor::f32(&[b, l, d], emb.clone()),
            lengths.clone(),
            labels,
        )
        .unwrap();
    let fwd = engine
        .forward(
            "tiny",
            (b, l),
            &params,
            Tensor::f32(&[b, l, d], emb),
            lengths,
        )
        .unwrap();
    assert_eq!(fwd.len(), train.logits.len());
    for (a, t) in fwd.iter().zip(&train.logits) {
        assert!((a - t).abs() < 1e-5, "fwd/train logits diverge: {a} vs {t}");
    }
}

#[test]
fn sgd_on_artifact_reduces_loss() {
    // Train purely through the artifact: loss must drop. This is the
    // minimal end-to-end "the compiled graph learns" proof.
    let dir = require_artifacts!();
    let engine = Engine::start(&dir).unwrap();
    let arts = engine.manifest().model("tiny").unwrap().clone();
    let mut params = arts.load_params(&dir).unwrap();
    let bucket = arts.buckets[0].clone();
    let (b, l, d) = (bucket.batch, bucket.len, arts.emb_dim);
    let (emb, lengths, labels) = make_inputs(b, l, d, arts.tasks, 3);

    let mut first = None;
    let mut last = 0.0;
    for _ in 0..12 {
        let out = engine
            .train_step(
                "tiny",
                (b, l),
                &params,
                Tensor::f32(&[b, l, d], emb.clone()),
                lengths.clone(),
                labels.clone(),
            )
            .unwrap();
        let loss = out.loss_sums.iter().sum::<f32>() / out.n_valid;
        first.get_or_insert(loss);
        last = loss;
        let lr = 0.05 / out.n_valid;
        for (p, g) in params.iter_mut().zip(&out.grads) {
            *p -= lr * g;
        }
    }
    let first = first.unwrap();
    assert!(
        last < first * 0.9,
        "loss did not drop: {first} -> {last}"
    );
}

#[test]
fn engine_is_shareable_across_threads() {
    let dir = require_artifacts!();
    let engine = Engine::start(&dir).unwrap();
    let arts = engine.manifest().model("tiny").unwrap().clone();
    let params = std::sync::Arc::new(arts.load_params(&dir).unwrap());
    let bucket = arts.buckets[0].clone();
    let (b, l, d) = (bucket.batch, bucket.len, arts.emb_dim);

    let mut joins = Vec::new();
    for t in 0..4u64 {
        let engine = engine.clone();
        let params = std::sync::Arc::clone(&params);
        joins.push(std::thread::spawn(move || {
            let (emb, lengths, labels) = make_inputs(b, l, d, 2, 100 + t);
            let out = engine
                .train_step(
                    "tiny",
                    (b, l),
                    &params,
                    Tensor::f32(&[b, l, d], emb),
                    lengths,
                    labels,
                )
                .unwrap();
            assert!(out.loss_sums.iter().all(|x| x.is_finite()));
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
}

#[test]
fn manifest_param_counts_match_rust_formula() {
    let dir = require_artifacts!();
    let manifest = Manifest::load(&dir).unwrap();
    use mtgrboost::config::ModelConfig;
    for (name, arts) in &manifest.models {
        if let Some(cfg) = ModelConfig::by_name(name) {
            assert_eq!(
                cfg.dense_params(),
                arts.param_count,
                "python/rust param-count drift for `{name}`"
            );
            assert_eq!(cfg.emb_dim, arts.emb_dim);
        }
    }
}

#[test]
fn unknown_artifacts_error_cleanly() {
    let dir = require_artifacts!();
    let engine = Engine::start(&dir).unwrap();
    assert!(engine
        .execute("no_such_model", ArtifactKind::Train, (4, 32), vec![])
        .is_err());
}
