//! End-to-end online-learning suite: `--mode online` runs with feature
//! admission, TTL expiry and periodic delta sync must be (1)
//! bit-identical across `--threads {1, 4}` — loss trace, embedding
//! checksum, counters, and the delta snapshot *bytes* themselves — and
//! (2) exactly reconstructible: replaying the emitted deltas in order
//! onto an empty table rebuilds every rank's final shard state
//! row-for-row (checksum witness).

use std::path::PathBuf;

use mtgrboost::checkpoint::delta::{
    apply_delta, list_delta_seqs, load_delta_meta, load_delta_shard,
};
use mtgrboost::data::generator::GeneratorConfig;
use mtgrboost::embedding::concurrent::ConcurrentDynamicTable;
use mtgrboost::embedding::dynamic_table::DynamicTableConfig;
use mtgrboost::online::{AdmissionConfig, OnlineOptions};
use mtgrboost::optim::adam::{AdamParams, SparseAdam};
use mtgrboost::runtime::Engine;
use mtgrboost::train::{TrainReport, Trainer, TrainerOptions};

fn tmp(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("mtgr_online_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    d
}

/// ~30 intervals × 3 steps of online training at toy scale: small
/// populations with aggressive new-ID arrival so admission and expiry
/// both trigger inside the test budget.
fn online_opts(threads: usize, sync_dir: Option<PathBuf>) -> TrainerOptions {
    let mut o = TrainerOptions::new("tiny", 2, 0);
    o.generator = GeneratorConfig {
        len_mu: 2.5,
        len_sigma: 0.5,
        min_len: 2,
        max_len: 60,
        num_users: 400,
        num_items: 250,
        new_user_rate: 0.3,
        new_item_rate: 0.3,
        ..Default::default()
    };
    o.train.target_tokens = 900;
    o.train.lr = 0.01;
    o.shard_capacity = 1024;
    o.collect_gauc = false;
    o.threads = threads;
    let mut online = OnlineOptions::new(3);
    online.intervals = 30;
    online.feature_ttl = 9;
    online.admission = Some(AdmissionConfig::new(2, 0.05));
    online.day_every = 2;
    online.sync_dir = sync_dir;
    o.online = Some(online);
    o
}

fn run(threads: usize, sync_dir: Option<PathBuf>) -> TrainReport {
    let engine = Engine::reference(7).unwrap();
    Trainer::new(online_opts(threads, sync_dir), engine)
        .unwrap()
        .run()
        .unwrap()
}

/// Bit-level fingerprint: losses, samples, checksum, and every online
/// counter.
fn fingerprint(r: &TrainReport) -> (Vec<(u64, u64, u64, u64, u64, u64)>, u64, u64, u64) {
    (
        r.steps
            .iter()
            .map(|s| {
                (
                    s.loss_ctr.to_bits(),
                    s.loss_ctcvr.to_bits(),
                    s.samples,
                    s.online_admitted,
                    s.online_expired,
                    s.online_sync_bytes,
                )
            })
            .collect(),
        r.embedding_checksum,
        r.online_admitted,
        r.online_rejected,
    )
}

#[test]
fn online_run_bit_identical_across_thread_counts_and_exercises_all_paths() {
    let dir1 = tmp("t1");
    let dir4 = tmp("t4");
    let r1 = run(1, Some(dir1.clone()));
    let r4 = run(4, Some(dir4.clone()));

    assert_eq!(r1.steps.len(), 90, "30 intervals × 3 steps");
    assert_eq!(
        fingerprint(&r1),
        fingerprint(&r4),
        "online run must be bit-identical across --threads {{1,4}}"
    );

    // The run actually exercised the online machinery.
    assert!(r1.online_admitted > 0, "admissions must happen");
    assert!(r1.online_rejected > 0, "one-shot ids must be rejected");
    assert!(r1.online_expired > 0, "TTL must retire stale rows");
    assert!(r1.online_synced_rows > 0, "deltas must carry rows");
    assert!(r1.online_sync_bytes > 0);
    assert!(
        r1.steps.iter().any(|s| s.sim_sync_s > 0.0),
        "sync traffic must be accounted in simulated time"
    );
    // Off-boundary steps carry no counters.
    assert!(r1
        .steps
        .iter()
        .enumerate()
        .filter(|(i, _)| (i + 1) % 3 != 0)
        .all(|(_, s)| s.online_sync_bytes == 0 && s.sim_sync_s == 0.0));

    // Strongest witness: the delta snapshot FILES are byte-identical
    // across thread counts.
    let seqs = list_delta_seqs(&dir1).unwrap();
    assert_eq!(seqs.len(), 30, "one delta per interval");
    assert_eq!(seqs, list_delta_seqs(&dir4).unwrap());
    for &seq in &seqs {
        let m1 = load_delta_meta(&dir1, seq).unwrap();
        for rank in 0..m1.world {
            let p = format!("delta_{seq:05}/sparse_rank{rank:05}_of{}.bin", m1.world);
            let b1 = std::fs::read(dir1.join(&p)).unwrap();
            let b4 = std::fs::read(dir4.join(&p)).unwrap();
            assert_eq!(b1, b4, "delta {seq} rank {rank} bytes diverged");
        }
    }
    std::fs::remove_dir_all(dir1).ok();
    std::fs::remove_dir_all(dir4).ok();
}

#[test]
fn replaying_deltas_reconstructs_the_final_trainer_state() {
    let dir = tmp("recon");
    let report = run(1, Some(dir.clone()));

    // The base state is empty (deltas start at interval 1 and the
    // tracker has recorded every mutation since step 0), so replaying
    // all deltas in order rebuilds each rank's shard exactly.
    let seqs = list_delta_seqs(&dir).unwrap();
    let meta0 = load_delta_meta(&dir, seqs[0]).unwrap();
    let mut checksum = 0u64;
    let mut rows = 0usize;
    for rank in 0..meta0.world {
        // Seed/capacity are irrelevant: rows install with exact bits.
        let table = ConcurrentDynamicTable::new(
            DynamicTableConfig::new(meta0.dim).with_capacity(64).with_seed(0xDEAD),
            8,
        );
        let mut opt = SparseAdam::new(meta0.dim, AdamParams::default());
        for &seq in &seqs {
            let m = load_delta_meta(&dir, seq).unwrap();
            let (upserts, removed) = load_delta_shard(&dir, &m, rank).unwrap();
            apply_delta(&table, &mut opt, upserts, &removed);
        }
        checksum = checksum.wrapping_add(table.content_checksum());
        rows += table.len();
    }
    assert_eq!(
        checksum, report.embedding_checksum,
        "base + ordered deltas must reconstruct the exact final embedding state"
    );
    assert_eq!(rows, report.table_rows, "row counts must match");
    assert!(rows > 0);
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn ttl_bounds_resident_rows_versus_no_ttl() {
    // Identical stream; the only difference is the sweeper. The TTL run
    // must end with fewer resident rows and report expiries.
    let with_ttl = run(1, None);
    let engine = Engine::reference(7).unwrap();
    let mut o = online_opts(1, None);
    if let Some(online) = &mut o.online {
        online.feature_ttl = 0;
    }
    let no_ttl = Trainer::new(o, engine).unwrap().run().unwrap();
    assert_eq!(no_ttl.online_expired, 0, "no TTL, no expiries");
    assert!(with_ttl.online_expired > 0);
    assert!(
        with_ttl.table_rows < no_ttl.table_rows,
        "TTL must bound the table: {} vs {}",
        with_ttl.table_rows,
        no_ttl.table_rows
    );
}

#[test]
fn offline_runs_report_zero_online_activity() {
    let mut o = TrainerOptions::new("tiny", 2, 6);
    o.generator = GeneratorConfig {
        len_mu: 2.5,
        len_sigma: 0.5,
        min_len: 2,
        max_len: 60,
        num_users: 400,
        num_items: 250,
        ..Default::default()
    };
    o.train.target_tokens = 600;
    o.collect_gauc = false;
    let engine = Engine::reference(7).unwrap();
    let r = Trainer::new(o, engine).unwrap().run().unwrap();
    assert_eq!(r.online_admitted, 0);
    assert_eq!(r.online_rejected, 0);
    assert_eq!(r.online_expired, 0);
    assert_eq!(r.online_sync_bytes, 0);
    assert!(r.steps.iter().all(|s| s.sim_sync_s == 0.0));
    // Offline table stats still surface (inserts happen; nothing evicts
    // at this scale).
    assert!(r.table_stats.inserts > 0);
}

#[test]
fn trainer_rejects_contradictory_online_options() {
    let engine = Engine::reference(7).unwrap();
    let mut o = TrainerOptions::new("tiny", 2, 10);
    o.online = Some(OnlineOptions::new(0));
    assert!(Trainer::new(o, engine).is_err(), "zero sync interval");

    let engine = Engine::reference(7).unwrap();
    let mut o = TrainerOptions::new("tiny", 2, 10);
    let mut online = OnlineOptions::new(10);
    online.feature_ttl = 3;
    o.online = Some(online);
    assert!(Trainer::new(o, engine).is_err(), "ttl below sync interval");
}
