//! Multi-group checkpoint + delta-sync integration: with ≥ 3 merge
//! groups (heterogeneous dims), (a) a full save/restore reproduces every
//! group's rows AND Adam m/v/t byte-exactly, and (b) a base snapshot
//! plus ordered deltas replayed on a serving replica reconstructs the
//! same per-group state — verified at the *byte level* by re-serializing
//! the reconstructed state and comparing every checkpoint file, plus a
//! world-size reshard through the modulo rule per group.

use mtgrboost::checkpoint::delta::{
    apply_delta, collect_rows, install_rows_concurrent, load_delta_group_dims,
    load_delta_meta, load_delta_shard_group, save_delta_groups, save_full_groups,
    snapshot_rows, DeltaMeta, GroupDelta,
};
use mtgrboost::checkpoint::{
    load_dense, load_group_dims, load_meta, load_sparse_shard_group, CheckpointMeta,
};
use mtgrboost::embedding::concurrent::ConcurrentDynamicTable;
use mtgrboost::embedding::dynamic_table::DynamicTableConfig;
use mtgrboost::embedding::sharded::shard_owner;
use mtgrboost::optim::adam::{AdamParams, DenseAdam, SparseAdam};
use mtgrboost::util::pool::WorkerPool;

/// Three heterogeneous merge groups — the satellite's ≥ 3 requirement.
const GROUP_DIMS: [usize; 3] = [4, 8, 16];
const WORLD: usize = 2;

fn tmp(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!(
        "mtgr_mg_ckpt_{tag}_{}",
        std::process::id()
    ));
    std::fs::remove_dir_all(&d).ok();
    d
}

/// One rank's state: a (table, optimizer) pair per merge group.
struct RankState {
    groups: Vec<(ConcurrentDynamicTable, SparseAdam)>,
}

impl RankState {
    fn new(seed: u64) -> RankState {
        RankState {
            groups: GROUP_DIMS
                .iter()
                .map(|&dim| {
                    (
                        ConcurrentDynamicTable::new(
                            DynamicTableConfig::new(dim)
                                .with_capacity(128)
                                .with_seed(seed),
                            4,
                        ),
                        SparseAdam::new(dim, AdamParams::default()),
                    )
                })
                .collect(),
        }
    }

    /// Insert + Adam-update `ids` this rank owns in group `g`.
    fn train(&mut self, rank: usize, g: usize, ids: &[u64], gscale: f32) {
        let dim = GROUP_DIMS[g];
        let pool = WorkerPool::new(1);
        let mine: Vec<u64> = ids
            .iter()
            .copied()
            .filter(|&id| shard_owner(id, WORLD) == rank)
            .collect();
        let (table, opt) = &mut self.groups[g];
        let mut buf = vec![0.0f32; dim];
        for &id in &mine {
            table.lookup_or_insert(id, &mut buf);
        }
        let grads: Vec<f32> = mine
            .iter()
            .flat_map(|&id| (0..dim).map(move |j| gscale * ((id + j as u64) % 5 + 1) as f32))
            .collect();
        opt.step_concurrent(&pool, &*table, &mine, &grads, 1.0);
    }

    fn remove(&mut self, rank: usize, g: usize, ids: &[u64]) {
        let (table, opt) = &mut self.groups[g];
        for &id in ids {
            if shard_owner(id, WORLD) == rank {
                table.remove(id);
                opt.drop_row(id);
            }
        }
    }

    fn group_refs(&self) -> Vec<(&ConcurrentDynamicTable, &SparseAdam)> {
        self.groups.iter().map(|(t, o)| (t, o)).collect()
    }
}

fn meta(step: u64) -> CheckpointMeta {
    CheckpointMeta {
        world: WORLD,
        step,
        model: "tiny".into(),
        // `dim` carries the model dim; per-group dims ride `group_dims`.
        dim: 16,
        param_count: 3,
    }
}

fn dmeta(seq: u64, step: u64) -> DeltaMeta {
    DeltaMeta {
        seq,
        world: WORLD,
        step,
        base_step: step.saturating_sub(10),
        model: "tiny".into(),
        dim: 16,
        param_count: 3,
    }
}

/// Group-g id space (groups have independent tables; disjoint ranges
/// mimic the Eq. 8 global-id partition).
fn gid(g: usize, x: u64) -> u64 {
    ((g as u64) << 40) | x
}

fn save_world_full(
    dir: &std::path::Path,
    ranks: &[RankState],
    cm: &CheckpointMeta,
    params: &[f32],
    dopt: &DenseAdam,
) {
    for (rank, st) in ranks.iter().enumerate() {
        let dense = (rank == 0).then_some((params, dopt));
        save_full_groups(dir, cm, rank, dense, &st.group_refs()).unwrap();
    }
}

/// Every file of a checkpoint/delta dir, sorted by name → bytes.
fn dir_bytes(dir: &std::path::Path) -> Vec<(String, Vec<u8>)> {
    let mut out: Vec<(String, Vec<u8>)> = std::fs::read_dir(dir)
        .unwrap()
        .map(|e| {
            let e = e.unwrap();
            (
                e.file_name().to_string_lossy().into_owned(),
                std::fs::read(e.path()).unwrap(),
            )
        })
        .collect();
    out.sort_by(|a, b| a.0.cmp(&b.0));
    out
}

/// Build the "trained" world: 3 groups × 2 ranks with overlapping id
/// traffic and a couple of optimizer steps.
fn trained_world() -> Vec<RankState> {
    let mut ranks: Vec<RankState> = (0..WORLD).map(|r| RankState::new(7 + r as u64)).collect();
    for (rank, st) in ranks.iter_mut().enumerate() {
        for g in 0..GROUP_DIMS.len() {
            let ids: Vec<u64> = (0..40u64).map(|x| gid(g, x)).collect();
            st.train(rank, g, &ids, 0.1);
            // Second update on a subset: nontrivial m/v/t (t = 2).
            let subset: Vec<u64> = (0..20u64).map(|x| gid(g, x)).collect();
            st.train(rank, g, &subset, -0.05);
        }
    }
    ranks
}

#[test]
fn full_save_restore_roundtrips_three_groups_byte_exactly() {
    let dir = tmp("full");
    let ranks = trained_world();
    let params = [1.0f32, -2.0, 0.5];
    let dopt = DenseAdam::new(3, AdamParams::default());
    let cm = meta(100);
    save_world_full(&dir, &ranks, &cm, &params, &dopt);

    // Metadata carries the per-group dims.
    let m2 = load_meta(&dir).unwrap();
    assert_eq!(m2.step, 100);
    assert_eq!(load_group_dims(&dir, &m2).unwrap(), GROUP_DIMS.to_vec());
    let (p, _) = load_dense(&dir, m2.param_count).unwrap();
    assert_eq!(p, params);

    // Restore into a DIFFERENT-seed replica and compare state exactly.
    let mut restored: Vec<RankState> =
        (0..WORLD).map(|_| RankState::new(999)).collect();
    for (rank, st) in restored.iter_mut().enumerate() {
        for g in 0..GROUP_DIMS.len() {
            let rows = load_sparse_shard_group(&dir, &m2, WORLD, rank, g).unwrap();
            assert!(!rows.is_empty(), "group {g} rank {rank} restored rows");
            assert!(
                rows.iter().all(|r| r.row.len() == GROUP_DIMS[g]),
                "group {g}: restored rows at the group dim"
            );
            assert!(
                rows.iter().any(|r| r.t == 2),
                "group {g}: Adam step counts survived"
            );
            let (table, opt) = &mut st.groups[g];
            install_rows_concurrent(rows, table, opt);
        }
    }
    for (a, b) in ranks.iter().zip(&restored) {
        for g in 0..GROUP_DIMS.len() {
            assert_eq!(
                snapshot_rows(&a.groups[g].0, &a.groups[g].1),
                snapshot_rows(&b.groups[g].0, &b.groups[g].1),
                "group {g}: rows + Adam m/v/t must restore exactly"
            );
            assert_eq!(
                a.groups[g].0.content_checksum(),
                b.groups[g].0.content_checksum()
            );
        }
    }

    // Byte-level witness: re-serializing the restored state writes the
    // identical checkpoint files.
    let dir2 = tmp("full2");
    save_world_full(&dir2, &restored, &cm, &params, &dopt);
    assert_eq!(dir_bytes(&dir), dir_bytes(&dir2), "checkpoint bytes differ");
    // 2 ranks × 3 groups sparse files + meta + dense.
    assert_eq!(dir_bytes(&dir).len(), WORLD * GROUP_DIMS.len() + 2);

    // Reshard 2 → 1: each group's rows all land on the single new rank.
    for g in 0..GROUP_DIMS.len() {
        let rows = load_sparse_shard_group(&dir, &m2, 1, 0, g).unwrap();
        let expect: usize = ranks
            .iter()
            .map(|st| st.groups[g].0.len())
            .sum();
        assert_eq!(rows.len(), expect, "group {g}: reshard to world 1");
    }

    std::fs::remove_dir_all(dir).ok();
    std::fs::remove_dir_all(dir2).ok();
}

#[test]
fn base_plus_ordered_deltas_reconstructs_three_groups() {
    let sync = tmp("sync");
    let params = [0.25f32, 1.5, -0.75];
    let dopt = DenseAdam::new(3, AdamParams::default());

    // Interval 0: base state + full snapshot.
    let mut ranks = trained_world();
    let base_rows: Vec<Vec<Vec<mtgrboost::checkpoint::SparseRow>>> = ranks
        .iter()
        .map(|st| {
            (0..GROUP_DIMS.len())
                .map(|g| snapshot_rows(&st.groups[g].0, &st.groups[g].1))
                .collect()
        })
        .collect();

    // Interval 1: per-group churn — update a window, insert fresh ids,
    // remove a few — then a delta per rank (collecting rows for the ids
    // touched this interval, removals recorded).
    let mut write_delta = |ranks: &mut Vec<RankState>,
                           seq: u64,
                           step: u64,
                           upd: std::ops::Range<u64>,
                           fresh: std::ops::Range<u64>,
                           gone: std::ops::Range<u64>| {
        let mut touched: Vec<Vec<Vec<u64>>> = Vec::new(); // [rank][group]
        let mut removed: Vec<Vec<Vec<u64>>> = Vec::new();
        for (rank, st) in ranks.iter_mut().enumerate() {
            let mut t_rank = Vec::new();
            let mut r_rank = Vec::new();
            for g in 0..GROUP_DIMS.len() {
                let upd_ids: Vec<u64> = upd.clone().map(|x| gid(g, x)).collect();
                let fresh_ids: Vec<u64> = fresh.clone().map(|x| gid(g, x)).collect();
                let gone_ids: Vec<u64> = gone.clone().map(|x| gid(g, x)).collect();
                st.train(rank, g, &upd_ids, 0.2);
                st.train(rank, g, &fresh_ids, 0.3);
                st.remove(rank, g, &gone_ids);
                let mine = |ids: &[u64]| -> Vec<u64> {
                    ids.iter()
                        .copied()
                        .filter(|&id| shard_owner(id, WORLD) == rank)
                        .collect()
                };
                let mut touched_ids = mine(&upd_ids);
                touched_ids.extend(mine(&fresh_ids));
                touched_ids.sort_unstable();
                touched_ids.dedup();
                // Ids removed this interval must not ride the upserts.
                let gone_mine = mine(&gone_ids);
                touched_ids.retain(|id| !gone_mine.contains(id));
                t_rank.push(touched_ids);
                r_rank.push(gone_mine);
            }
            touched.push(t_rank);
            removed.push(r_rank);
        }
        for (rank, st) in ranks.iter().enumerate() {
            let rows: Vec<Vec<mtgrboost::checkpoint::SparseRow>> = (0..GROUP_DIMS.len())
                .map(|g| collect_rows(&st.groups[g].0, &st.groups[g].1, &touched[rank][g]))
                .collect();
            let shards: Vec<GroupDelta> = (0..GROUP_DIMS.len())
                .map(|g| GroupDelta {
                    dim: GROUP_DIMS[g],
                    upserts: &rows[g],
                    removed: &removed[rank][g],
                })
                .collect();
            let dm = dmeta(seq, step);
            let dense = (rank == 0).then_some((&params[..], &dopt));
            let bytes = save_delta_groups(&sync, &dm, rank, dense, &shards).unwrap();
            assert!(bytes > 0);
        }
    };

    write_delta(&mut ranks, 1, 10, 5..25, 40..55, 0..3);
    write_delta(&mut ranks, 2, 20, 10..45, 55..60, 3..6);

    // Delta metadata carries the group dims.
    let dm1 = load_delta_meta(&sync, 1).unwrap();
    assert_eq!(load_delta_group_dims(&sync, &dm1).unwrap(), GROUP_DIMS.to_vec());

    // Serving replica: install the base, apply deltas in seq order.
    let mut serve: Vec<RankState> = (0..WORLD).map(|_| RankState::new(4242)).collect();
    for (rank, st) in serve.iter_mut().enumerate() {
        for g in 0..GROUP_DIMS.len() {
            let (table, opt) = &mut st.groups[g];
            install_rows_concurrent(base_rows[rank][g].clone(), table, opt);
        }
        for seq in [1u64, 2] {
            let dm = load_delta_meta(&sync, seq).unwrap();
            for g in 0..GROUP_DIMS.len() {
                let (rows, rem) = load_delta_shard_group(&sync, &dm, rank, g).unwrap();
                let (table, opt) = &mut st.groups[g];
                apply_delta(table, opt, rows, &rem);
            }
        }
    }
    for (rank, (a, b)) in ranks.iter().zip(&serve).enumerate() {
        for g in 0..GROUP_DIMS.len() {
            assert_eq!(
                snapshot_rows(&a.groups[g].0, &a.groups[g].1),
                snapshot_rows(&b.groups[g].0, &b.groups[g].1),
                "rank {rank} group {g}: base + ordered deltas must reconstruct"
            );
        }
    }

    // Byte-level witness: full checkpoints of trainer and replica are
    // file-for-file identical.
    let (d1, d2) = (tmp("recon_a"), tmp("recon_b"));
    let cm = meta(20);
    save_world_full(&d1, &ranks, &cm, &params, &dopt);
    save_world_full(&d2, &serve, &cm, &params, &dopt);
    assert_eq!(dir_bytes(&d1), dir_bytes(&d2), "reconstructed bytes differ");

    std::fs::remove_dir_all(sync).ok();
    std::fs::remove_dir_all(d1).ok();
    std::fs::remove_dir_all(d2).ok();
}
