//! Property-based invariant tests (hand-rolled generators over the
//! seeded RNG — the offline registry has no proptest). Each property
//! runs across many randomized cases; failures print the seed for
//! reproduction.

use std::collections::HashMap;

use mtgrboost::balance::{Batcher, DynamicBatcher};
use mtgrboost::data::schema::Sequence;
use mtgrboost::embedding::dedup::{gather_rows, scatter_accumulate, Dedup};
use mtgrboost::embedding::dynamic_table::{
    DynamicEmbeddingTable, DynamicTableConfig, EvictionPolicy,
};
use mtgrboost::embedding::hash::hash_id;
use mtgrboost::embedding::merge::{FeatureConfig, GlobalIdCodec, MergePlan};
use mtgrboost::embedding::sharded::shard_owner;
use mtgrboost::embedding::EmbeddingStore;
use mtgrboost::util::f16::{f16_bits_to_f32, f32_to_f16_bits};
use mtgrboost::util::rng::Xoshiro256;

fn seq_of(len: usize, user: u64) -> Sequence {
    Sequence {
        user_id: user,
        context: vec![0, 0, 0],
        tokens: vec![vec![0, 0, 0, 0]; len],
        labels: [0.0, 0.0],
    }
}

/// Property: the dynamic table behaves exactly like a HashMap under any
/// interleaving of insert / lookup / delta / remove, for random dims,
/// capacities, probe-group counts and eviction policies (without budget).
#[test]
fn prop_dynamic_table_hashmap_equivalence() {
    for case in 0..30 {
        let mut rng = Xoshiro256::new(1000 + case);
        let dim = rng.range_usize(1, 9);
        let cap = 1 << rng.range_usize(4, 8);
        let groups = 1 << rng.range_usize(0, 3);
        let policy = if rng.bernoulli(0.5) {
            EvictionPolicy::Lru
        } else {
            EvictionPolicy::Lfu
        };
        let mut table = DynamicEmbeddingTable::new(
            DynamicTableConfig::new(dim)
                .with_capacity(cap)
                .with_probe_groups(groups)
                .with_eviction(policy)
                .with_seed(case),
        );
        let mut reference: HashMap<u64, Vec<f32>> = HashMap::new();
        let mut buf = vec![0.0f32; dim];
        for _ in 0..2000 {
            let id = rng.gen_range(300);
            match rng.gen_range(12) {
                0..=6 => {
                    let existed = table.lookup_or_insert(id, &mut buf);
                    assert_eq!(existed, reference.contains_key(&id), "case {case}");
                    reference.entry(id).or_insert_with(|| buf.clone());
                    assert_eq!(&buf, reference.get(&id).unwrap(), "case {case}");
                }
                7..=8 => {
                    let delta: Vec<f32> = (0..dim).map(|_| rng.next_f32() - 0.5).collect();
                    let ok = table.apply_delta(id, &delta);
                    assert_eq!(ok, reference.contains_key(&id));
                    if let Some(row) = reference.get_mut(&id) {
                        for (r, d) in row.iter_mut().zip(&delta) {
                            *r += d;
                        }
                    }
                }
                9..=10 => {
                    assert_eq!(table.remove(id), reference.remove(&id).is_some());
                }
                _ => {
                    let found = table.lookup(id, &mut buf);
                    assert_eq!(found, reference.contains_key(&id));
                }
            }
            assert_eq!(table.len(), reference.len(), "case {case}");
        }
    }
}

/// Property: Algorithm 1 conserves sequences (no loss, no duplication,
/// order preserved) for any chunking and any target.
#[test]
fn prop_batcher_conservation() {
    for case in 0..40 {
        let mut rng = Xoshiro256::new(2000 + case);
        let target = rng.range_usize(50, 2000);
        let n = rng.range_usize(1, 300);
        let lens: Vec<usize> = (0..n).map(|_| rng.range_usize(1, 200)).collect();
        let mut b = DynamicBatcher::new(target);
        let mut emitted: Vec<u64> = Vec::new();
        let mut i = 0usize;
        while i < n {
            let chunk = rng.range_usize(1, 50).min(n - i);
            b.push_chunk(
                (i..i + chunk)
                    .map(|k| seq_of(lens[k], k as u64))
                    .collect(),
            );
            i += chunk;
            while let Some(batch) = b.next_batch() {
                // Every emitted batch holds at least one sequence and,
                // unless it is a single oversized sequence, lands within
                // 2x of target.
                assert!(!batch.sequences.is_empty());
                if batch.sequences.len() > 1 {
                    assert!(
                        batch.tokens <= 2 * target,
                        "case {case}: batch {} tokens vs target {target}",
                        batch.tokens
                    );
                }
                emitted.extend(batch.sequences.iter().map(|s| s.user_id));
            }
        }
        if let Some(batch) = b.flush() {
            emitted.extend(batch.sequences.iter().map(|s| s.user_id));
        }
        let expect: Vec<u64> = (0..n as u64).collect();
        assert_eq!(emitted, expect, "case {case}");
    }
}

/// Property: dedup round-trips and gather/scatter stay adjoint for any
/// id distribution and dim.
#[test]
fn prop_dedup_roundtrip_and_adjoint() {
    for case in 0..40 {
        let mut rng = Xoshiro256::new(3000 + case);
        let n = rng.range_usize(0, 500);
        let vocab = rng.range_usize(1, 100) as u64;
        let dim = rng.range_usize(1, 6);
        let ids: Vec<u64> = (0..n).map(|_| rng.gen_range(vocab)).collect();
        let d = Dedup::of(&ids);
        assert_eq!(d.reconstruct(), ids, "case {case}");
        // Unique ids are unique.
        let mut u = d.unique.clone();
        u.sort_unstable();
        u.dedup();
        assert_eq!(u.len(), d.unique.len());
        if n == 0 {
            continue;
        }
        let rows: Vec<f32> = (0..d.unique.len() * dim).map(|_| rng.next_f32() - 0.5).collect();
        let grads: Vec<f32> = (0..n * dim).map(|_| rng.next_f32() - 0.5).collect();
        let mut expanded = vec![0.0f32; n * dim];
        gather_rows(&rows, dim, &d.inverse, &mut expanded);
        let mut acc = vec![0.0f32; d.unique.len() * dim];
        scatter_accumulate(&grads, dim, &d.inverse, &mut acc);
        let lhs: f64 = expanded.iter().zip(&grads).map(|(a, b)| (*a * b) as f64).sum();
        let rhs: f64 = rows.iter().zip(&acc).map(|(a, b)| (*a * b) as f64).sum();
        assert!((lhs - rhs).abs() < 1e-3, "case {case}: {lhs} vs {rhs}");
    }
}

/// Property: Eq. 8 codec is bijective and preserves the sign bit for
/// every table count up to 1000.
#[test]
fn prop_codec_bijective() {
    let mut rng = Xoshiro256::new(4000);
    for _ in 0..60 {
        let m = rng.range_usize(1, 1000);
        let c = GlobalIdCodec::new(m);
        for _ in 0..50 {
            let t = rng.range_usize(0, m);
            let x = rng.next_u64() & c.max_local_id();
            let enc = c.encode(t, x);
            assert_eq!(enc >> 63, 0, "sign bit must stay clear");
            assert_eq!(c.decode(enc), (t, x));
        }
    }
}

/// Property: Eq. 8 roundtrips across ALL table counts `m` in 1..=1025 —
/// in particular every `k = ⌈log₂(m+1)⌉` boundary (m = 2^k − 1 uses k
/// bits, m = 2^k needs k+1) — and at max-magnitude raw IDs (the full
/// 63−k local-id range), where an off-by-one in the shift would corrupt
/// the table index or the sign bit.
#[test]
fn prop_codec_roundtrip_all_table_counts_and_boundaries() {
    let mut rng = Xoshiro256::new(4100);
    for m in 1usize..=1025 {
        let c = GlobalIdCodec::new(m);
        let k = c.id_bits();
        // k is exactly ⌈log₂(m+1)⌉: 2^k ≥ m+1 and (k>1 ⟹ 2^(k−1) < m+1).
        assert!(1u64 << k >= (m as u64 + 1), "m={m}: 2^{k} < m+1");
        if k > 1 {
            assert!(
                1u64 << (k - 1) < (m as u64 + 1),
                "m={m}: k={k} not minimal"
            );
        }
        assert_eq!(c.max_local_id(), (1u64 << (63 - k)) - 1, "m={m}");
        let max_local = c.max_local_id();
        let locals = [0u64, 1, max_local / 2, max_local - 1, max_local];
        let tables = [0usize, m / 2, m - 1];
        for &t in &tables {
            for &x in &locals {
                let enc = c.encode(t, x);
                assert_eq!(enc >> 63, 0, "m={m} t={t}: sign bit set");
                assert_eq!(c.decode(enc), (t, x), "m={m} t={t} x={x}");
            }
            // A random max-magnitude-masked raw ID per table.
            let x = rng.next_u64() & max_local;
            assert_eq!(c.decode(c.encode(t, x)), (t, x));
        }
        // Distinct tables never collide, even at identical local IDs.
        if m > 1 {
            assert_ne!(c.encode(0, max_local), c.encode(m - 1, max_local));
        }
    }
}

/// Property: shard routing is a pure function and the paper's modulo
/// refinement holds for power-of-two worlds: owner under 2w maps to
/// owner under w by reduction mod w.
#[test]
fn prop_shard_owner_pow2_refinement() {
    let mut rng = Xoshiro256::new(5000);
    for _ in 0..2000 {
        let id = rng.next_u64();
        for w in [1usize, 2, 4, 8, 16, 32, 64] {
            let a = shard_owner(id, w);
            let b = shard_owner(id, 2 * w);
            assert_eq!(b % w, a, "id {id} w {w}");
            assert!(a < w);
        }
    }
}

/// Property: f16 round-trip is idempotent (quantize twice == once) and
/// monotone on finite values.
#[test]
fn prop_f16_idempotent_monotone() {
    let mut rng = Xoshiro256::new(6000);
    let mut prev_in = f32::NEG_INFINITY;
    let mut prev_out = f32::NEG_INFINITY;
    let mut vals: Vec<f32> = (0..5000)
        .map(|_| (rng.next_f32() - 0.5) * rng.range_f64(0.0, 100000.0) as f32)
        .collect();
    vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
    for v in vals {
        let q = f16_bits_to_f32(f32_to_f16_bits(v));
        let qq = f16_bits_to_f32(f32_to_f16_bits(q));
        assert_eq!(q.to_bits(), qq.to_bits(), "idempotent at {v}");
        if v > prev_in {
            assert!(q >= prev_out, "monotone: f({v}) = {q} < f({prev_in}) = {prev_out}");
            prev_in = v;
            prev_out = q;
        }
    }
}

/// Property: hash_id avalanche — single-bit input flips change ~half the
/// output bits on average (guards against accidental weakening).
#[test]
fn prop_hash_avalanche() {
    let mut rng = Xoshiro256::new(7000);
    let mut total = 0u64;
    let trials = 4000;
    for _ in 0..trials {
        let x = rng.next_u64();
        let bit = 1u64 << rng.gen_range(64);
        total += (hash_id(x, 9) ^ hash_id(x ^ bit, 9)).count_ones() as u64;
    }
    let mean = total as f64 / trials as f64;
    assert!((mean - 32.0).abs() < 1.5, "avalanche mean {mean}");
}

/// Property: MergePlan invariants over randomized heterogeneous feature
/// sets — every feature lands in exactly one group, `shared_table`
/// aliases resolve to the same (group, logical table), and the Eq. 8
/// codec roundtrips at max-magnitude local IDs for every table count
/// m ∈ 1..=33 (covering the k = ⌈log₂(m+1)⌉ bit boundaries at 1, 3, 7,
/// 15, 31).
#[test]
fn prop_merge_plan_invariants() {
    const DIMS: [usize; 5] = [4, 8, 16, 32, 64];
    for m in 1usize..=33 {
        let mut rng = Xoshiro256::new(9000 + m as u64);
        // m host tables with random dims, plus a few alias features.
        let mut features: Vec<FeatureConfig> = (0..m)
            .map(|i| FeatureConfig::new(&format!("f{i}"), DIMS[rng.gen_range(5) as usize]))
            .collect();
        let n_alias = rng.range_usize(0, 4.min(m + 1));
        for a in 0..n_alias {
            let host = rng.range_usize(0, m);
            let dim = features[host].dim;
            features.push(FeatureConfig::new(&format!("alias{a}"), dim).shared(&format!("f{host}")));
        }
        let plan = MergePlan::build(&features);

        // Codec: built over the m *logical* tables (aliases add none).
        assert_eq!(plan.ops_before, m, "m={m}: logical table count");
        assert_eq!(
            plan.ops_after,
            plan.groups.len(),
            "m={m}: one fused op per group"
        );
        assert!(plan.ops_after <= plan.ops_before);

        // Every feature in exactly one group; group index consistent
        // with the group listing; aliases share (group, table) with
        // their host.
        for f in &features {
            let (g, t) = *plan.feature_to_table.get(&f.name).unwrap();
            assert!(g < plan.groups.len(), "m={m}: group index in range");
            assert_eq!(plan.groups[g].dim, f.dim, "m={m}: feature in its dim group");
            let key = f.table_key();
            assert!(
                plan.groups[g].tables.contains(&key),
                "m={m}: `{}` listed in its group",
                f.name
            );
            // The logical table appears in exactly ONE group overall.
            let appearances: usize = plan
                .groups
                .iter()
                .map(|grp| grp.tables.iter().filter(|k| **k == key).count())
                .sum();
            assert_eq!(appearances, 1, "m={m}: `{key}` in exactly one group");
            if let Some(host) = &f.shared_table {
                let host_feat = features.iter().find(|h| &h.name == host).unwrap();
                assert_eq!(
                    (g, t),
                    *plan.feature_to_table.get(&host_feat.name).unwrap(),
                    "m={m}: alias `{}` shares (group, table) with `{host}`",
                    f.name
                );
            }
        }

        // Codec roundtrip across groups at extreme local IDs: 0, 1, a
        // random mid value, and the max-magnitude id for this k.
        let max_local = plan.codec.max_local_id();
        for f in &features {
            let (_g, t_global) = *plan.feature_to_table.get(&f.name).unwrap();
            for local in [0u64, 1, rng.next_u64() & max_local, max_local] {
                let gid = plan.codec.encode(t_global, local);
                assert_eq!(gid >> 63, 0, "m={m}: sign bit stays clear");
                assert_eq!(
                    plan.codec.decode(gid),
                    (t_global, local),
                    "m={m}: roundtrip table {t_global} local {local}"
                );
            }
        }
        // Distinct tables never collide even at identical local ids.
        if m > 1 {
            let a = plan.codec.encode(0, max_local);
            let b = plan.codec.encode(m - 1, max_local);
            assert_ne!(a, b, "m={m}");
        }
    }
}
