//! Scenario-engine suite: the HSTU dense model's bit-identity grid,
//! per-preset smoke runs asserting each scenario engages the machinery
//! it stresses, trainer-level validation of contradictory combinations,
//! and the long-run soak asserting resident state stays bounded over a
//! multi-day simulated run.

use mtgrboost::online::OnlineOptions;
use mtgrboost::runtime::Engine;
use mtgrboost::scenario::Scenario;
use mtgrboost::train::{TrainReport, Trainer, TrainerOptions};

/// Bit-level fingerprint: per-step losses, token layout AND the
/// scenario telemetry lanes (carry-over, resident rows, day,
/// evictions), plus the final sparse-state checksum.
fn fingerprint(r: &TrainReport) -> (Vec<(u64, u64, u64, [u64; 4])>, u64) {
    (
        r.steps
            .iter()
            .map(|s| {
                (
                    s.loss_ctr.to_bits(),
                    s.loss_ctcvr.to_bits(),
                    s.samples,
                    [s.batcher_carryover, s.resident_rows, s.online_day, s.evictions],
                )
            })
            .collect(),
        r.embedding_checksum,
    )
}

fn base_opts(model: &str, steps: usize) -> TrainerOptions {
    let mut o = TrainerOptions::new(model, 2, steps);
    o.generator.len_mu = 2.5;
    o.generator.len_sigma = 0.5;
    o.generator.min_len = 2;
    o.generator.max_len = 60;
    o.generator.num_users = 500;
    o.generator.num_items = 300;
    o.train.target_tokens = 900;
    o.train.lr = 0.01;
    o.shard_capacity = 1024;
    o.collect_gauc = false;
    o
}

fn run(o: TrainerOptions) -> TrainReport {
    let engine = Engine::reference(7).unwrap();
    Trainer::new(o, engine).unwrap().run().unwrap()
}

// ---- HSTU dense model ---------------------------------------------------

/// The tentpole acceptance grid: the HSTU-style attention block
/// (pointwise-gated attention over variable-length sequences, exact
/// recomputed backward) must be bit-identical across `--threads {1,4}`
/// × `--overlap` × `--cross-step` — parallel dense compute with
/// realistic FLOPs, same arithmetic on every schedule.
#[test]
fn hstu_grid_bit_identical() {
    let grid_run = |overlap: bool, threads: usize, cross_step: bool| {
        let mut o = base_opts("tiny-hstu", 8);
        o.overlap = overlap;
        o.threads = threads;
        o.cross_step = cross_step;
        run(o)
    };
    let reference = grid_run(false, 1, false);
    let reference_fp = fingerprint(&reference);
    assert_eq!(reference.steps.len(), 8);
    assert!(
        reference
            .steps
            .iter()
            .all(|s| s.loss_ctr.is_finite() && s.loss_ctr > 0.0),
        "HSTU training must produce finite positive losses"
    );
    assert_ne!(reference.embedding_checksum, 0);
    for (overlap, threads, cross_step) in [
        (true, 1, true),
        (false, 4, false),
        (true, 4, false),
        (true, 4, true),
    ] {
        let r = grid_run(overlap, threads, cross_step);
        assert_eq!(
            fingerprint(&r),
            reference_fp,
            "hstu: overlap={overlap} threads={threads} cross={cross_step} \
             diverged from threads=1/overlap=off"
        );
        assert_eq!(r.table_rows, reference.table_rows);
    }
    // The attention block actually changes the function being trained:
    // the same data through the mean-pool tiny model lands elsewhere.
    let pooled = {
        let o = base_opts("tiny", 8);
        run(o)
    };
    assert_ne!(
        pooled.steps.last().unwrap().loss_ctr.to_bits(),
        reference.steps.last().unwrap().loss_ctr.to_bits(),
        "hstu and mean-pool models must not coincide"
    );
}

// ---- Preset smoke runs --------------------------------------------------

#[test]
fn skew_storm_stresses_the_batcher_and_stays_identical() {
    let storm = |threads: usize| {
        let mut o = base_opts("tiny", 6);
        o.scenario = Some(Scenario::by_name("skew-storm").unwrap());
        o.threads = threads;
        run(o)
    };
    let a = storm(1);
    let b = storm(4);
    assert_eq!(fingerprint(&a), fingerprint(&b), "skew-storm thread divergence");
    assert_eq!(a.scenario.as_deref(), Some("skew-storm"));
    // The heavy tail must actually reach the batcher: tokens are
    // carried across batch cuts, and no step record is malformed.
    assert!(
        a.batcher_carryover_mean > 0.0,
        "skew-storm never carried tokens over"
    );
    assert!(a.batcher_fill_mean > 0.0, "fill metric must be populated");
}

#[test]
fn multi_tenant_budget_evicts_across_tiers() {
    let tenant = |threads: usize| {
        let mut o = base_opts("tiny", 8);
        // Wide ID space so the per-group budget is actually exceeded.
        o.generator.num_users = 20_000;
        o.generator.num_items = 50_000;
        o.train.target_tokens = 2048;
        o.shard_capacity = 1 << 12;
        o.scenario = Some(Scenario::by_name("multi-tenant").unwrap());
        o.threads = threads;
        run(o)
    };
    let a = tenant(1);
    let b = tenant(4);
    assert_eq!(fingerprint(&a), fingerprint(&b), "multi-tenant thread divergence");
    assert_eq!(
        a.group_dims,
        vec![1, 8, 32],
        "the tiered schema forms three dim groups on the tiny model"
    );
    assert!(
        a.total_evictions > 0,
        "the per-group row budget never evicted"
    );
    assert!(a.peak_resident_rows > 0);
}

#[test]
fn churn_storm_churns_admission_across_days() {
    let churn = |threads: usize| {
        let mut o = base_opts("tiny", 0);
        let mut oo = OnlineOptions::new(5);
        oo.intervals = 3;
        o.online = Some(oo);
        o.scenario = Some(Scenario::by_name("churn-storm").unwrap());
        o.threads = threads;
        run(o)
    };
    let a = churn(1);
    let b = churn(4);
    assert_eq!(fingerprint(&a), fingerprint(&b), "churn-storm thread divergence");
    assert_eq!(a.steps.len(), 15);
    // The flash-sale flood engages admission in both directions, and
    // the fast day cadence drives the sketch's day-decay clock.
    assert!(a.online_admitted > 0, "no admissions under churn");
    assert!(a.online_rejected > 0, "admission filtered nothing");
    assert!(
        a.steps.iter().map(|s| s.online_day).max().unwrap() >= 1,
        "day cadence never advanced"
    );
}

// ---- Trainer-level validation ------------------------------------------

#[test]
fn contradictory_scenario_combinations_are_refused() {
    // Online-only preset without --mode online.
    let mut o = base_opts("tiny", 10);
    o.scenario = Some(Scenario::by_name("soak").unwrap());
    assert!(
        Trainer::new(o, Engine::reference(7).unwrap()).is_err(),
        "soak must require online mode"
    );
    // Offline-only preset under online mode.
    let mut o = base_opts("tiny", 0);
    o.online = Some(OnlineOptions::new(5));
    o.scenario = Some(Scenario::by_name("multi-tenant").unwrap());
    assert!(
        Trainer::new(o, Engine::reference(7).unwrap()).is_err(),
        "multi-tenant must refuse online mode"
    );
    // A schema that disagrees with the scenario's forced one.
    let mut o = base_opts("tiny", 10);
    o.schema = "meituan-mixed".to_string();
    o.scenario = Some(Scenario::by_name("multi-tenant").unwrap());
    assert!(
        Trainer::new(o, Engine::reference(7).unwrap()).is_err(),
        "conflicting --schema must be refused"
    );
    // The forced schema spelled out explicitly is fine.
    let mut o = base_opts("tiny", 4);
    o.schema = "meituan-tiered".to_string();
    o.scenario = Some(Scenario::by_name("multi-tenant").unwrap());
    assert!(Trainer::new(o, Engine::reference(7).unwrap()).is_ok());
}

// ---- Long-run soak ------------------------------------------------------

/// The bounded-memory acceptance test: over a multi-day simulated run,
/// TTL expiry + admission day decay must keep resident rows bounded —
/// doubling the run length must NOT proportionally grow the peak
/// resident-row count, and the TTL sweeper must actually retire rows.
#[test]
fn soak_run_keeps_resident_rows_bounded() {
    let soak = |intervals: usize, threads: usize| {
        let mut o = base_opts("tiny", 0);
        // Bounded ID spaces with sustained churn (the scenario sets the
        // churn rates): revisited IDs stay alive, one-shot IDs expire.
        o.generator.num_users = 2_000;
        o.generator.num_items = 3_000;
        let mut oo = OnlineOptions::new(5);
        oo.intervals = intervals;
        o.online = Some(oo);
        o.scenario = Some(Scenario::by_name("soak").unwrap());
        o.threads = threads;
        run(o)
    };
    let short = soak(6, 1);
    let long = soak(12, 1);
    assert_eq!(short.steps.len(), 30);
    assert_eq!(long.steps.len(), 60);

    // The soak preset defaults a TTL (4 × sync interval), so the
    // sweeper must have retired rows in the longer run.
    assert!(long.online_expired > 0, "TTL retired nothing over the soak");
    assert!(long.online_admitted > 0 && long.online_rejected > 0);
    // Day clock advanced repeatedly (multi-day run).
    assert!(
        long.steps.iter().map(|s| s.online_day).max().unwrap() >= 2,
        "soak must cross several simulated days"
    );

    // Boundedness: twice the steps must not grow peak residency
    // anywhere near proportionally — the steady state is set by
    // TTL × admission, not by run length.
    assert!(short.peak_resident_rows > 0);
    assert!(
        long.peak_resident_rows <= short.peak_resident_rows * 3 / 2,
        "resident rows grew with run length: peak {} over 30 steps vs \
         peak {} over 60 steps",
        short.peak_resident_rows,
        long.peak_resident_rows
    );
    // And the run ends near steady state, not at a fresh high-water
    // mark: the final resident count stays within the peak seen by
    // mid-run.
    let mid_peak = long.steps[..30]
        .iter()
        .map(|s| s.resident_rows)
        .max()
        .unwrap();
    let late_peak = long.steps[30..]
        .iter()
        .map(|s| s.resident_rows)
        .max()
        .unwrap();
    assert!(
        late_peak <= mid_peak * 3 / 2,
        "second-half residency kept climbing: {late_peak} vs {mid_peak}"
    );

    // The soak stays deterministic across thread counts too.
    let wide = soak(6, 4);
    assert_eq!(fingerprint(&short), fingerprint(&wide), "soak thread divergence");
}
