//! End-to-end determinism: a 2-worker, 20-step distributed `Trainer`
//! run over the reference engine is **bit-identical** across runs with
//! the same seed, bit-identical between `--overlap on` and
//! `--overlap off` and between `--cross-step on` and `off` (the
//! pipelined exchange reorders messages, never arithmetic), and
//! bit-identical across `--threads {1,4,0}` (the global worker pool's
//! fair-share views chunk work — with fixed chunk counts on every
//! reduction — never changing reduction order).
//!
//! Everything that feeds the numbers is seeded and rank-order
//! deterministic: the workload generator (streamed through the
//! prefetcher's order-preserving channel), row initialization (a pure
//! function of id and seed), stripe-grouped parallel fetch (fixed
//! stripe count, per-stripe occurrence order), the rank-ordered
//! all-reduce, and the fixed-order reference executor. The
//! `embedding_checksum` witnesses the final sparse state
//! order-independently. GAUC is disabled because its accumulator
//! iterates a std `HashMap` (per-process random order) — that affects
//! only the metric's floating-point summation order, not training.

use mtgrboost::data::generator::GeneratorConfig;
use mtgrboost::runtime::Engine;
use mtgrboost::train::{TrainReport, Trainer, TrainerOptions};

fn opts(overlap: bool, threads: usize) -> TrainerOptions {
    let mut o = TrainerOptions::new("tiny", 2, 20);
    o.generator = GeneratorConfig {
        len_mu: 2.5,
        len_sigma: 0.5,
        min_len: 2,
        max_len: 60,
        num_users: 500,
        num_items: 300,
        ..Default::default()
    };
    // ~64 sequences (mean length ≈ 13) per step → 2-3 micro-batches per
    // round, so the overlap pipeline genuinely posts ahead (the hidden-
    // communication metrics only credit rounds that were posted early).
    o.train.target_tokens = 900;
    o.train.lr = 0.01;
    o.shard_capacity = 1024;
    o.collect_gauc = false;
    o.overlap = overlap;
    o.threads = threads;
    o
}

fn run(overlap: bool, threads: usize) -> TrainReport {
    let engine = Engine::reference(7).unwrap();
    Trainer::new(opts(overlap, threads), engine)
        .unwrap()
        .run()
        .unwrap()
}

/// Bit-level fingerprint of everything numerically meaningful per step,
/// plus the final sparse-state checksum.
fn fingerprint(r: &TrainReport) -> (Vec<(u64, u64, u64, Vec<u64>)>, u64) {
    (
        r.steps
            .iter()
            .map(|s| {
                (
                    s.loss_ctr.to_bits(),
                    s.loss_ctcvr.to_bits(),
                    s.samples,
                    s.tokens.clone(),
                )
            })
            .collect(),
        r.embedding_checksum,
    )
}

#[test]
fn same_seed_runs_are_bit_identical() {
    let a = run(true, 1);
    let b = run(true, 1);
    assert_eq!(a.steps.len(), 20);
    assert_eq!(fingerprint(&a), fingerprint(&b));
    assert_eq!(a.table_rows, b.table_rows);
    assert_eq!(a.table_memory_bytes, b.table_memory_bytes);
    assert_eq!(a.dedup_volume, b.dedup_volume);
    // The run is real training: finite positive losses, rows inserted.
    assert!(a
        .steps
        .iter()
        .all(|s| s.loss_ctr.is_finite() && s.loss_ctr > 0.0));
    assert!(a.table_rows > 50, "sparse shards filled: {}", a.table_rows);
    assert_ne!(a.embedding_checksum, 0, "checksum must witness state");
}

#[test]
fn overlap_on_and_off_are_bit_identical() {
    let on = run(true, 1);
    let off = run(false, 1);
    assert_eq!(fingerprint(&on), fingerprint(&off));
    assert_eq!(on.table_rows, off.table_rows);
    assert_eq!(on.dedup_volume, off.dedup_volume);
    // Scheduling differs even though arithmetic does not: the
    // double-buffered rounds hide the ID exchange, the embedding reply
    // and the gradient push behind compute, exposing less communication.
    assert!(on.mean_hidden_comm_s() > 0.0, "overlap must hide ID comm");
    assert!(
        on.mean_hidden_reply_s() > 0.0,
        "double-buffered rounds must hide reply comm"
    );
    assert!(
        on.mean_hidden_grad_s() > 0.0,
        "posted backward must hide gradient comm"
    );
    assert_eq!(off.mean_hidden_comm_s(), 0.0, "no hiding when off");
    assert_eq!(off.mean_hidden_reply_s(), 0.0, "no hiding when off");
    assert_eq!(off.mean_hidden_grad_s(), 0.0, "no hiding when off");
    assert!(
        on.mean_exposed_comm_s() < off.mean_exposed_comm_s(),
        "exposed comm must shrink with overlap: {} vs {}",
        on.mean_exposed_comm_s(),
        off.mean_exposed_comm_s()
    );
}

#[test]
fn threads_and_overlap_grid_bit_identical() {
    // The acceptance grid: `--threads {1,4,0}` (0 = machine-sized
    // global pool) × `--overlap {on,off}` × `--cross-step {on,off}` all
    // produce identical losses AND identical final embedding state.
    // Batches are sized up (vs the other tests) so the thresholded
    // pooled kernels actually engage at threads=4: per-round occurrence
    // counts clear the stripe-fetch and gather/scatter-parallel
    // thresholds, not just the always-on concurrent optimizer. (The
    // sorted-dedup kernel's cross-thread identity is additionally
    // covered by its own unit suite with 20k-id inputs.)
    let grid_run = |overlap: bool, threads: usize, cross_step: bool| {
        let mut o = opts(overlap, threads);
        o.cross_step = cross_step;
        o.train.target_tokens = 2600;
        o.steps = 10;
        let engine = Engine::reference(7).unwrap();
        Trainer::new(o, engine).unwrap().run().unwrap()
    };
    let reference = grid_run(false, 1, false);
    let reference_fp = fingerprint(&reference);
    assert_ne!(reference.embedding_checksum, 0);
    for (overlap, threads, cross_step) in [
        (true, 1, true),
        (false, 4, false),
        (true, 4, false),
        (true, 4, true),
        (true, 0, true), // machine-sized global pool
    ] {
        let r = grid_run(overlap, threads, cross_step);
        assert_eq!(
            fingerprint(&r),
            reference_fp,
            "overlap={overlap} threads={threads} cross={cross_step} diverged \
             from threads=1/overlap=off"
        );
        assert_eq!(r.table_rows, reference.table_rows);
        assert_eq!(r.table_memory_bytes, reference.table_memory_bytes);
        assert_eq!(r.dedup_volume, reference.dedup_volume);
        if overlap && cross_step {
            assert!(
                r.mean_hidden_boundary_s() > 0.0,
                "cross-step must report boundary-hidden time"
            );
        } else {
            assert_eq!(
                r.mean_hidden_boundary_s(),
                0.0,
                "no boundary hiding without cross-step overlap"
            );
        }
    }
}

#[test]
fn mixed_schema_grid_bit_identical() {
    // The heterogeneous-dim path (ISSUE 5 acceptance grid): `--schema
    // meituan-mixed` forms TWO merge groups on the tiny model (8D
    // context features, 32D token features incl. the exp_item alias),
    // and the full `--threads {1,4} × --overlap {on,off} ×
    // --cross-step {on,off}` grid must produce bit-identical losses AND
    // bit-identical *per-group* embedding checksums.
    let grid_run = |overlap: bool, threads: usize, cross_step: bool| {
        let mut o = opts(overlap, threads);
        o.schema = "meituan-mixed".to_string();
        o.cross_step = cross_step;
        // Several micro rounds per step so the per-group double-buffered
        // exchanges genuinely pipeline.
        o.train.target_tokens = 1400;
        o.steps = 8;
        let engine = Engine::reference(7).unwrap();
        Trainer::new(o, engine).unwrap().run().unwrap()
    };
    let reference = grid_run(false, 1, false);
    assert_eq!(
        reference.group_dims,
        vec![8, 32],
        "tiny model: an 8D context group and a 32D token group"
    );
    assert!(
        reference.group_rows.iter().all(|&r| r > 0),
        "both groups must fill rows: {:?}",
        reference.group_rows
    );
    assert!(
        reference.group_checksums.iter().all(|&c| c != 0),
        "per-group checksums must witness state"
    );
    assert!(
        reference.lookup_ops_merged < reference.lookup_ops_unmerged,
        "2 fused ops per round must undercut the 7 per-table ops: {} vs {}",
        reference.lookup_ops_merged,
        reference.lookup_ops_unmerged
    );
    // Dedup must engage inside each group independently.
    for (g, v) in reference.group_volumes.iter().enumerate() {
        assert!(v.ids_sent < v.ids_raw, "group {g}: stage-1 dedup inert");
        assert!(v.lookups_done < v.lookups_raw, "group {g}: stage-2 dedup inert");
    }
    let reference_fp = (fingerprint(&reference), reference.group_checksums.clone());
    for overlap in [false, true] {
        for threads in [1usize, 4] {
            for cross_step in [false, true] {
                if !overlap && threads == 1 && !cross_step {
                    continue; // the reference itself
                }
                let r = grid_run(overlap, threads, cross_step);
                assert_eq!(
                    (fingerprint(&r), r.group_checksums.clone()),
                    reference_fp,
                    "overlap={overlap} threads={threads} cross={cross_step} \
                     diverged from threads=1/overlap=off"
                );
                assert_eq!(r.group_rows, reference.group_rows);
                assert_eq!(r.group_volumes, reference.group_volumes);
                assert_eq!(r.table_rows, reference.table_rows);
            }
        }
    }
}

#[test]
fn multiplexed_exchange_bit_identical_and_payload_conserved() {
    // The raw-speed pass acceptance grid: `--multiplex` (the default)
    // packs every merge group's exchange into ONE message per comm lane;
    // `--no-multiplex` keeps one exchange per group. On the two-group
    // meituan-mixed schema, with overlap + cross-step + threads=4 all
    // on, both modes must produce bit-identical losses and per-group
    // checksums — and, lane by lane, move exactly the same payload
    // bytes (the packed path may only add its per-group section
    // headers, metered separately).
    let grid_run = |mux: bool| {
        let mut o = opts(true, 4);
        o.schema = "meituan-mixed".to_string();
        o.cross_step = true;
        o.multiplex_exchange = mux;
        o.train.target_tokens = 1400;
        o.steps = 8;
        let engine = Engine::reference(7).unwrap();
        Trainer::new(o, engine).unwrap().run().unwrap()
    };
    let muxed = grid_run(true);
    let plain = grid_run(false);
    assert_eq!(
        (fingerprint(&muxed), muxed.group_checksums.clone()),
        (fingerprint(&plain), plain.group_checksums.clone()),
        "multiplexing changed arithmetic"
    );
    assert_eq!(muxed.group_rows, plain.group_rows);
    assert_eq!(muxed.group_volumes, plain.group_volumes);
    // Payload conservation on the four exchange lanes (ids, reply,
    // grad-ids, grads), per step and over the run. Lane 0 is excluded:
    // it carries the bookkeeping collectives.
    assert_eq!(muxed.steps.len(), plain.steps.len());
    for (sm, sp) in muxed.steps.iter().zip(&plain.steps) {
        assert_eq!(
            sm.wire_payload_bytes[1..],
            sp.wire_payload_bytes[1..],
            "step {}: packed exchange moved different payload",
            sm.step
        );
    }
    for lane in 1..5 {
        assert_eq!(muxed.wire_payload_bytes[lane], plain.wire_payload_bytes[lane]);
        assert!(
            muxed.wire_payload_bytes[lane] > 0,
            "lane {lane} must carry exchange traffic"
        );
    }
    // Two groups → the packed path really engaged (headers on the wire)
    // while the per-group path added none.
    assert!(muxed.wire_header_bytes > 0, "packed headers must be metered");
    assert_eq!(plain.wire_header_bytes, 0, "per-group path has no headers");
}

#[test]
fn unmerged_ablation_bit_identical() {
    // `--no-merging` keeps one group (and one exchange per round) per
    // logical table. Global IDs are identical under both plans — only
    // the grouping differs — so losses and the aggregate embedding
    // state must match the merged run bit for bit, while the operator
    // counts lose the fusion win.
    let grid_run = |merging: bool| {
        let mut o = opts(true, 1);
        o.schema = "meituan-mixed".to_string();
        o.cross_step = true;
        o.table_merging = merging;
        o.train.target_tokens = 1400;
        o.steps = 8;
        let engine = Engine::reference(7).unwrap();
        Trainer::new(o, engine).unwrap().run().unwrap()
    };
    let merged = grid_run(true);
    let unmerged = grid_run(false);
    assert_eq!(
        fingerprint(&merged),
        fingerprint(&unmerged),
        "table merging changed arithmetic"
    );
    assert_eq!(merged.table_rows, unmerged.table_rows);
    assert!(
        unmerged.group_dims.len() > merged.group_dims.len(),
        "unmerged must split groups: {:?} vs {:?}",
        unmerged.group_dims,
        merged.group_dims
    );
    assert_eq!(
        unmerged.lookup_ops_merged, unmerged.lookup_ops_unmerged,
        "no fusion win without merging"
    );
    assert!(merged.lookup_ops_merged < merged.lookup_ops_unmerged);
    // One table per group → the same run repeated is still
    // deterministic through the unmerged path.
    let again = grid_run(false);
    assert_eq!(
        (fingerprint(&again), again.group_checksums.clone()),
        (fingerprint(&unmerged), unmerged.group_checksums.clone())
    );
}

#[test]
fn default_schema_unaffected_by_multi_group_plumbing() {
    // The single-group compatibility guarantee, observable side: the
    // default schema reports exactly one group whose checksum equals
    // the aggregate checksum, and fused ops == 1 per round while the
    // unmerged count reflects the 7 logical tables.
    let r = run(true, 1);
    assert_eq!(r.group_dims.len(), 1);
    assert_eq!(r.group_checksums[0], r.embedding_checksum);
    assert_eq!(r.group_rows[0], r.table_rows);
    assert_eq!(r.lookup_ops_unmerged, 7 * r.lookup_ops_merged);
}

#[test]
fn mixed_precision_grid_bit_identical() {
    // The ISSUE 10 acceptance grid: `--precision mixed` (FP32 hot rows,
    // FP16 cold rows, post-bump threshold classification) must be
    // bit-identical across `--threads {1,4}` × `--overlap {on,off}` ×
    // `--cross-step {on,off}` on the two-group meituan-mixed schema —
    // quantization is a pure function of stored state and the
    // rank-order-deterministic access census, never of scheduling.
    use mtgrboost::embedding::precision::PrecisionMode;
    let grid_run = |overlap: bool, threads: usize, cross_step: bool, mixed: bool| {
        let mut o = opts(overlap, threads);
        o.schema = "meituan-mixed".to_string();
        o.cross_step = cross_step;
        if mixed {
            o.precision = PrecisionMode::Mixed;
            o.hot_threshold = 3;
        }
        o.train.target_tokens = 1400;
        o.steps = 8;
        let engine = Engine::reference(7).unwrap();
        Trainer::new(o, engine).unwrap().run().unwrap()
    };
    let reference = grid_run(false, 1, false, true);
    assert_eq!(reference.precision, "mixed");
    // The policy genuinely engaged: both classes populated, FP16 rows
    // and per-row tags on the wire, hot rows still shipped full width.
    assert!(
        reference.hot_rows > 0 && reference.cold_rows > 0,
        "census must see both classes: {} hot / {} cold",
        reference.hot_rows,
        reference.cold_rows
    );
    assert_eq!(
        reference.hot_rows + reference.cold_rows,
        reference.table_rows as u64,
        "census must partition the resident rows"
    );
    assert!(reference.quantize_ops > 0, "cold writes must quantize");
    assert!(reference.wire_fp16_row_bytes > 0, "cold rows must ship packed");
    assert!(reference.wire_tag_bytes > 0, "per-row tags must be metered");
    assert!(reference.wire_fp32_row_bytes > 0, "hot rows must stay FP32");
    // Effective storage strictly undercuts the all-FP32 footprint.
    let all_fp32: u64 = reference
        .group_rows
        .iter()
        .zip(&reference.group_dims)
        .map(|(&rows, &dim)| (rows * dim * 4) as u64)
        .sum();
    assert!(
        reference.effective_value_bytes < all_fp32,
        "mixed storage must beat all-fp32: {} vs {all_fp32}",
        reference.effective_value_bytes
    );
    let reference_fp = (fingerprint(&reference), reference.group_checksums.clone());
    for overlap in [false, true] {
        for threads in [1usize, 4] {
            for cross_step in [false, true] {
                if !overlap && threads == 1 && !cross_step {
                    continue; // the reference itself
                }
                let r = grid_run(overlap, threads, cross_step, true);
                assert_eq!(
                    (fingerprint(&r), r.group_checksums.clone()),
                    reference_fp,
                    "mixed: overlap={overlap} threads={threads} cross={cross_step} \
                     diverged from threads=1/overlap=off"
                );
                assert_eq!(r.hot_rows, reference.hot_rows);
                assert_eq!(r.cold_rows, reference.cold_rows);
                assert_eq!(r.quantize_ops, reference.quantize_ops);
                assert_eq!(
                    (r.wire_fp32_row_bytes, r.wire_fp16_row_bytes, r.wire_tag_bytes),
                    (
                        reference.wire_fp32_row_bytes,
                        reference.wire_fp16_row_bytes,
                        reference.wire_tag_bytes
                    ),
                    "mixed wire meters must not depend on scheduling"
                );
            }
        }
    }
    // fp32 (the default) on the same workload: precision meters pinned
    // to zero, and a genuinely different trajectory — binary16
    // quantization of cold rows must actually bite, otherwise the grid
    // above is vacuous.
    let fp32 = grid_run(false, 1, false, false);
    assert_eq!(fp32.precision, "fp32");
    assert_eq!(
        (
            fp32.wire_fp32_row_bytes,
            fp32.wire_fp16_row_bytes,
            fp32.wire_tag_bytes,
            fp32.hot_rows,
            fp32.cold_rows,
            fp32.quantize_ops
        ),
        (0, 0, 0, 0, 0, 0),
        "fp32 keeps every precision meter at zero"
    );
    assert_ne!(
        fingerprint(&fp32),
        fingerprint(&reference),
        "quantization must change the trajectory"
    );
}

#[test]
fn mixed_precision_multiplexed_exchange_conserves_and_compresses() {
    // Mixed precision composes with the packed exchange: `--multiplex`
    // vs `--no-multiplex` stays bit-identical and moves the same lane
    // payloads under `--precision mixed`. And because the ID stream is
    // a pure function of the seeded generator — independent of stored
    // values — the mixed run requests exactly the bytes of IDs the fp32
    // run does, while its reply lane (cold rows at half width) is
    // strictly smaller.
    use mtgrboost::embedding::precision::PrecisionMode;
    let grid_run = |mux: bool, mixed: bool| {
        let mut o = opts(true, 4);
        o.schema = "meituan-mixed".to_string();
        o.cross_step = true;
        o.multiplex_exchange = mux;
        if mixed {
            o.precision = PrecisionMode::Mixed;
            o.hot_threshold = 3;
        }
        o.train.target_tokens = 1400;
        o.steps = 8;
        let engine = Engine::reference(7).unwrap();
        Trainer::new(o, engine).unwrap().run().unwrap()
    };
    let muxed = grid_run(true, true);
    let plain = grid_run(false, true);
    assert_eq!(
        (fingerprint(&muxed), muxed.group_checksums.clone()),
        (fingerprint(&plain), plain.group_checksums.clone()),
        "multiplexing changed mixed-precision arithmetic"
    );
    for lane in 1..5 {
        assert_eq!(
            muxed.wire_payload_bytes[lane], plain.wire_payload_bytes[lane],
            "lane {lane}: packed mixed exchange moved different payload"
        );
    }
    assert_eq!(
        (muxed.wire_fp32_row_bytes, muxed.wire_fp16_row_bytes, muxed.wire_tag_bytes),
        (plain.wire_fp32_row_bytes, plain.wire_fp16_row_bytes, plain.wire_tag_bytes),
        "precision meters must agree across multiplex modes"
    );
    // Against the fp32 baseline: identical ID traffic, compressed rows.
    let fp32 = grid_run(true, false);
    assert_eq!(
        muxed.wire_payload_bytes[1], fp32.wire_payload_bytes[1],
        "the ID lane is workload-determined, not precision-determined"
    );
    assert!(
        muxed.wire_payload_bytes[2] < fp32.wire_payload_bytes[2],
        "cold replies at half width must shrink the reply lane: {} vs {}",
        muxed.wire_payload_bytes[2],
        fp32.wire_payload_bytes[2]
    );
    assert!(
        muxed.wire_payload_bytes[4] < fp32.wire_payload_bytes[4],
        "cold gradient pushes must shrink the grad lane: {} vs {}",
        muxed.wire_payload_bytes[4],
        fp32.wire_payload_bytes[4]
    );
}

#[test]
fn different_seeds_actually_differ() {
    // Guard against the fingerprint being vacuous (e.g. constant zero).
    let a = run(true, 1);
    let mut o = opts(true, 1);
    o.generator.seed = 999;
    let engine = Engine::reference(7).unwrap();
    let b = Trainer::new(o, engine).unwrap().run().unwrap();
    assert_ne!(fingerprint(&a), fingerprint(&b));
}
