//! End-to-end determinism: a 2-worker, 20-step distributed `Trainer`
//! run over the reference engine is **bit-identical** across runs with
//! the same seed, and bit-identical between `--overlap on` and
//! `--overlap off` (the pipelined exchange reorders messages, never
//! arithmetic).
//!
//! Everything that feeds the numbers is seeded and rank-order
//! deterministic: the workload generator, row initialization (a pure
//! function of id and seed), the rank-ordered all-reduce, and the
//! fixed-order reference executor. GAUC is disabled because its
//! accumulator iterates a std `HashMap` (per-process random order) —
//! that affects only the metric's floating-point summation order, not
//! training.

use mtgrboost::data::generator::GeneratorConfig;
use mtgrboost::runtime::Engine;
use mtgrboost::train::{TrainReport, Trainer, TrainerOptions};

fn opts(overlap: bool) -> TrainerOptions {
    let mut o = TrainerOptions::new("tiny", 2, 20);
    o.generator = GeneratorConfig {
        len_mu: 2.5,
        len_sigma: 0.5,
        min_len: 2,
        max_len: 60,
        num_users: 500,
        num_items: 300,
        ..Default::default()
    };
    // ~64 sequences (mean length ≈ 13) per step → 2-3 micro-batches per
    // round, so the overlap pipeline genuinely posts ahead (the hidden-
    // communication metric only credits rounds that were posted early).
    o.train.target_tokens = 900;
    o.train.lr = 0.01;
    o.shard_capacity = 1024;
    o.collect_gauc = false;
    o.overlap = overlap;
    o
}

fn run(overlap: bool) -> TrainReport {
    let engine = Engine::reference(7).unwrap();
    Trainer::new(opts(overlap), engine).unwrap().run().unwrap()
}

/// Bit-level fingerprint of everything numerically meaningful per step.
fn fingerprint(r: &TrainReport) -> Vec<(u64, u64, u64, Vec<u64>)> {
    r.steps
        .iter()
        .map(|s| {
            (
                s.loss_ctr.to_bits(),
                s.loss_ctcvr.to_bits(),
                s.samples,
                s.tokens.clone(),
            )
        })
        .collect()
}

#[test]
fn same_seed_runs_are_bit_identical() {
    let a = run(true);
    let b = run(true);
    assert_eq!(a.steps.len(), 20);
    assert_eq!(fingerprint(&a), fingerprint(&b));
    assert_eq!(a.table_rows, b.table_rows);
    assert_eq!(a.table_memory_bytes, b.table_memory_bytes);
    assert_eq!(a.dedup_volume, b.dedup_volume);
    // The run is real training: finite positive losses, rows inserted.
    assert!(a
        .steps
        .iter()
        .all(|s| s.loss_ctr.is_finite() && s.loss_ctr > 0.0));
    assert!(a.table_rows > 50, "sparse shards filled: {}", a.table_rows);
}

#[test]
fn overlap_on_and_off_are_bit_identical() {
    let on = run(true);
    let off = run(false);
    assert_eq!(fingerprint(&on), fingerprint(&off));
    assert_eq!(on.table_rows, off.table_rows);
    assert_eq!(on.dedup_volume, off.dedup_volume);
    // Scheduling differs even though arithmetic does not: overlap hides
    // the ID exchange behind compute and exposes less communication.
    assert!(on.mean_hidden_comm_s() > 0.0, "overlap must hide ID comm");
    assert_eq!(off.mean_hidden_comm_s(), 0.0, "no hiding when off");
    assert!(
        on.mean_exposed_comm_s() < off.mean_exposed_comm_s(),
        "exposed comm must shrink with overlap: {} vs {}",
        on.mean_exposed_comm_s(),
        off.mean_exposed_comm_s()
    );
}

#[test]
fn different_seeds_actually_differ() {
    // Guard against the fingerprint being vacuous (e.g. constant zero).
    let a = run(true);
    let mut o = opts(true);
    o.generator.seed = 999;
    let engine = Engine::reference(7).unwrap();
    let b = Trainer::new(o, engine).unwrap().run().unwrap();
    assert_ne!(fingerprint(&a), fingerprint(&b));
}
