//! `DedupVolume` accounting audit: the stage-1 + stage-2 counters that
//! `ShardedEmbedding` reports must match an independent brute-force
//! recount of the exchanged messages (computed with plain `HashSet`s
//! from the input id lists), in both blocking and pipelined modes —
//! and the per-pair byte counters must agree with what actually crossed
//! the communicator.

use std::collections::HashSet;
use std::sync::Arc;
use std::thread;

use mtgrboost::collective::comm::{CommGroup, CommHandle};
use mtgrboost::embedding::dedup::{DedupStrategy, DedupVolume};
use mtgrboost::embedding::dynamic_table::{DynamicEmbeddingTable, DynamicTableConfig};
use mtgrboost::embedding::sharded::{shard_owner, ShardedEmbedding};

const DIM: usize = 4;
const WORLD: usize = 3;

/// The deterministic per-rank id batches every test uses (duplicates
/// within a batch, across batches, and across ranks).
fn batches_for(rank: usize) -> Vec<Vec<u64>> {
    let r = rank as u64;
    vec![
        (0..60).map(|i| (i % 13) + r).collect(),
        (0..40).map(|i| (i * 3) % 21).collect(),
    ]
}

/// Brute-force recount: replay the exchange bookkeeping for `rank`
/// using sets, no `Dedup` machinery.
fn expected_volume(rank: usize, strategy: DedupStrategy) -> DedupVolume {
    let mut v = DedupVolume::default();
    let n_batches = batches_for(0).len();
    for b in 0..n_batches {
        // Requester side: this rank's batch partitioned by owner.
        let my = &batches_for(rank)[b];
        v.ids_raw += my.len();
        for dst in 0..WORLD {
            let bucket: Vec<u64> = my
                .iter()
                .copied()
                .filter(|&id| shard_owner(id, WORLD) == dst)
                .collect();
            let sent = if strategy.stage1() {
                bucket.iter().collect::<HashSet<_>>().len()
            } else {
                bucket.len()
            };
            v.ids_sent += sent;
            v.emb_rows_raw += bucket.len();
            v.emb_rows_sent += sent;
        }
        // Server side: what every rank sends *to* this rank.
        let mut received_total = 0usize;
        let mut union: HashSet<u64> = HashSet::new();
        for src in 0..WORLD {
            let theirs = &batches_for(src)[b];
            let bucket: Vec<u64> = theirs
                .iter()
                .copied()
                .filter(|&id| shard_owner(id, WORLD) == rank)
                .collect();
            received_total += if strategy.stage1() {
                bucket.iter().collect::<HashSet<_>>().len()
            } else {
                bucket.len()
            };
            union.extend(bucket);
        }
        v.lookups_raw += received_total;
        v.lookups_done += if strategy.stage2() {
            union.len()
        } else {
            received_total
        };
    }
    v
}

/// Expected non-self bytes this rank pushes through the communicator:
/// its outgoing unique-id messages plus its embedding replies.
fn expected_wire_bytes(rank: usize, strategy: DedupStrategy) -> u64 {
    let mut bytes = 0u64;
    for b in 0..batches_for(0).len() {
        // IDs this rank sends to each other rank.
        let my = &batches_for(rank)[b];
        for dst in 0..WORLD {
            if dst == rank {
                continue;
            }
            let bucket: Vec<u64> = my
                .iter()
                .copied()
                .filter(|&id| shard_owner(id, WORLD) == dst)
                .collect();
            let sent = if strategy.stage1() {
                bucket.iter().collect::<HashSet<_>>().len()
            } else {
                bucket.len()
            };
            bytes += (sent * 8) as u64;
        }
        // Replies this rank returns: one row per id received.
        for src in 0..WORLD {
            if src == rank {
                continue;
            }
            let theirs = &batches_for(src)[b];
            let bucket: Vec<u64> = theirs
                .iter()
                .copied()
                .filter(|&id| shard_owner(id, WORLD) == rank)
                .collect();
            let sent = if strategy.stage1() {
                bucket.iter().collect::<HashSet<_>>().len()
            } else {
                bucket.len()
            };
            bytes += (sent * DIM * 4) as u64;
        }
    }
    bytes
}

fn run_world<T: Send + 'static>(
    f: impl Fn(usize, &mut ShardedEmbedding<DynamicEmbeddingTable>, &mut CommHandle) -> T
        + Send
        + Sync
        + 'static,
) -> Vec<T> {
    let f = Arc::new(f);
    CommGroup::new(WORLD)
        .into_iter()
        .enumerate()
        .map(|(rank, mut h)| {
            let f = Arc::clone(&f);
            thread::spawn(move || {
                let table = DynamicEmbeddingTable::new(
                    DynamicTableConfig::new(DIM).with_capacity(256).with_seed(1),
                );
                let mut se = ShardedEmbedding::new(table, DedupStrategy::TwoStage);
                f(rank, &mut se, &mut h)
            })
        })
        .collect::<Vec<_>>()
        .into_iter()
        .map(|j| j.join().unwrap())
        .collect()
}

fn audit(strategy: DedupStrategy, pipelined: bool) {
    let out = run_world(move |rank, se, comm| {
        se.strategy = strategy;
        let batches = batches_for(rank);
        if pipelined {
            let p0 = se.post_ids(comm, &batches[0]);
            let p1 = se.post_ids(comm, &batches[1]);
            let _ = se.complete_lookup(comm, p0, true);
            let _ = se.complete_lookup(comm, p1, true);
        } else {
            for b in &batches {
                let _ = se.lookup(comm, b, true);
            }
        }
        (rank, se.volume, comm.stats.all_to_all_bytes)
    });
    for (rank, volume, wire_bytes) in out {
        let expect = expected_volume(rank, strategy);
        assert_eq!(
            volume, expect,
            "rank {rank} {strategy:?} pipelined={pipelined}"
        );
        assert_eq!(
            wire_bytes,
            expected_wire_bytes(rank, strategy),
            "rank {rank} {strategy:?} pipelined={pipelined}: wire bytes"
        );
    }
}

#[test]
fn volume_matches_brute_force_recount_blocking() {
    for strategy in [
        DedupStrategy::None,
        DedupStrategy::CommUnique,
        DedupStrategy::LookupUnique,
        DedupStrategy::TwoStage,
    ] {
        audit(strategy, false);
    }
}

#[test]
fn volume_matches_brute_force_recount_pipelined() {
    for strategy in [
        DedupStrategy::None,
        DedupStrategy::CommUnique,
        DedupStrategy::LookupUnique,
        DedupStrategy::TwoStage,
    ] {
        audit(strategy, true);
    }
}

#[test]
fn per_destination_byte_meters_match_last_exchange() {
    // last_id_bytes / last_emb_bytes describe the most recent lookup.
    let out = run_world(|rank, se, comm| {
        let batches = batches_for(rank);
        for b in &batches {
            let _ = se.lookup(comm, b, true);
        }
        (rank, se.last_id_bytes.clone(), se.last_emb_bytes.clone())
    });
    for (rank, id_bytes, emb_bytes) in out {
        let last = &batches_for(rank)[1];
        for dst in 0..WORLD {
            let uniq = last
                .iter()
                .copied()
                .filter(|&id| shard_owner(id, WORLD) == dst)
                .collect::<HashSet<_>>()
                .len();
            assert_eq!(id_bytes[dst], uniq * 8, "rank {rank} dst {dst}");
        }
        // Replies mirror what each source requested of this rank.
        for src in 0..WORLD {
            let uniq = batches_for(src)[1]
                .iter()
                .copied()
                .filter(|&id| shard_owner(id, WORLD) == rank)
                .collect::<HashSet<_>>()
                .len();
            assert_eq!(emb_bytes[src], uniq * DIM * 4, "rank {rank} src {src}");
        }
    }
}
