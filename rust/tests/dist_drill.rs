//! Crash-recovery drills through the real binary.
//!
//! Each drill runs `train-dist` (N worker processes over the UDS
//! transport, supervised with heartbeats) against the single-process
//! `train` reference on the SAME argv, and asserts the bit-exact JSON
//! reports agree: per-step loss bits, final losses, per-group embedding
//! checksums. The fault drills inject a kill or a torn checkpoint
//! publish mid-run and additionally assert the supervisor recovered
//! (gang restart from the newest CRC-durable delta) and accounted for
//! it — `recoveries`, `replayed_steps` — while the final state stayed
//! identical to the uninterrupted run.

use std::path::{Path, PathBuf};
use std::process::Command;

use mtgrboost::dist::worker::parse_hex64;
use mtgrboost::util::json::Json;

const BIN: &str = env!("CARGO_BIN_EXE_mtgrboost");

/// Short temp dirs: Unix socket paths cap at ~108 bytes.
fn tmp(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("mtgr_dd_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// The shared training tail: 3 intervals × 5 steps of the tiny model.
/// `sync_interval >= 5` keeps `final_losses` (mean of the last ≤5 step
/// records) comparable even when a recovered run's records start at its
/// resume step.
fn train_tail(world: usize, sync_dir: &Path) -> Vec<String> {
    [
        "--model",
        "tiny",
        "--mode",
        "online",
        "--sync-interval",
        "5",
        "--intervals",
        "3",
        "--seed",
        "977",
        "--threads",
        "1",
        "--log-every",
        "0",
        "--target-tokens",
        "512",
        "--max-len",
        "32",
        "--len-mu",
        "2.5",
        "--gauc",
        "off",
    ]
    .iter()
    .map(|s| s.to_string())
    .chain([
        "--world".to_string(),
        world.to_string(),
        "--sync-dir".to_string(),
        sync_dir.display().to_string(),
    ])
    .collect()
}

fn run_to_json(subcmd: &str, args: &[String], report: &Path) -> Json {
    let out = Command::new(BIN)
        .arg(subcmd)
        .args(args)
        .arg("--report-json")
        .arg(report)
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "{subcmd} failed ({}):\n{}",
        out.status,
        String::from_utf8_lossy(&out.stderr)
    );
    Json::parse(&std::fs::read_to_string(report).unwrap()).unwrap()
}

/// Single-process reference on the same argv.
fn reference_report(dir: &Path, world: usize) -> Json {
    let sync = dir.join("ref_sync");
    std::fs::create_dir_all(&sync).unwrap();
    run_to_json("train", &train_tail(world, &sync), &dir.join("ref.json"))
}

/// Multi-process run, optionally with an injected fault plan.
fn dist_report(dir: &Path, world: usize, fault: Option<&str>) -> Json {
    let sync = dir.join("dist_sync");
    std::fs::create_dir_all(&sync).unwrap();
    let mut args = train_tail(world, &sync);
    args.push("--run-dir".to_string());
    args.push(dir.join("run").display().to_string());
    if let Some(plan) = fault {
        args.push("--fault".to_string());
        args.push(plan.to_string());
    }
    run_to_json("train-dist", &args, &dir.join("dist.json"))
}

fn checksums(j: &Json) -> Vec<u64> {
    j.get("group_checksums")
        .as_arr()
        .unwrap()
        .iter()
        .map(|c| parse_hex64(c.as_str().unwrap()).unwrap())
        .collect()
}

fn final_bits(j: &Json) -> (u64, u64) {
    (
        parse_hex64(j.expect_str("final_loss_ctr_bits").unwrap()).unwrap(),
        parse_hex64(j.expect_str("final_loss_ctcvr_bits").unwrap()).unwrap(),
    )
}

fn step_bits(j: &Json) -> Vec<(usize, u64, u64)> {
    j.get("steps")
        .as_arr()
        .unwrap()
        .iter()
        .map(|s| {
            (
                s.expect_usize("step").unwrap(),
                parse_hex64(s.expect_str("loss_ctr_bits").unwrap()).unwrap(),
                parse_hex64(s.expect_str("loss_ctcvr_bits").unwrap()).unwrap(),
            )
        })
        .collect()
}

fn counter(j: &Json, key: &str) -> u64 {
    j.get("dist").expect_usize(key).unwrap() as u64
}

/// The identity the whole subsystem defends: final losses, per-group
/// checksums, rows, and every step record both runs have, bit for bit.
fn assert_bit_identical(dist: &Json, reference: &Json) {
    assert_eq!(final_bits(dist), final_bits(reference), "final loss bits");
    assert_eq!(checksums(dist), checksums(reference), "group checksums");
    assert_eq!(
        dist.expect_usize("table_rows").unwrap(),
        reference.expect_usize("table_rows").unwrap(),
        "total resident rows"
    );
    assert_eq!(
        dist.expect_usize("online_synced_rows").unwrap(),
        reference.expect_usize("online_synced_rows").unwrap(),
        "synced rows"
    );
    // A recovered run's step records start at its resume step; every
    // step both runs recorded must agree exactly.
    let ref_steps = step_bits(reference);
    let dist_steps = step_bits(dist);
    assert!(!dist_steps.is_empty(), "dist run recorded steps");
    for (step, ctr, ctcvr) in &dist_steps {
        let r = ref_steps
            .iter()
            .find(|(s, _, _)| s == step)
            .unwrap_or_else(|| panic!("reference has no record for step {step}"));
        assert_eq!((ctr, ctcvr), (&r.1, &r.2), "loss bits diverged at step {step}");
    }
}

#[test]
fn world2_clean_run_matches_single_process_bit_for_bit() {
    let d = tmp("clean2");
    let reference = reference_report(&d, 2);
    let dist = dist_report(&d, 2, None);
    assert_eq!(counter(&dist, "recoveries"), 0, "no faults, no recoveries");
    assert_eq!(counter(&dist, "replayed_steps"), 0);
    assert_eq!(
        step_bits(&dist).len(),
        step_bits(&reference).len(),
        "clean dist run records every step"
    );
    assert_bit_identical(&dist, &reference);
    std::fs::remove_dir_all(&d).ok();
}

#[test]
fn world2_kill_mid_interval_recovers_bit_identically() {
    let d = tmp("kill2");
    let reference = reference_report(&d, 2);
    // Step 7 is mid-interval 2: delta 1 is durable, steps 5..7 must be
    // replayed after the gang restart.
    let dist = dist_report(&d, 2, Some("kill:rank=1,step=7"));
    assert_eq!(counter(&dist, "recoveries"), 1, "one gang restart");
    assert!(
        counter(&dist, "replayed_steps") > 0,
        "the kill landed mid-interval, so steps past delta 1 were replayed"
    );
    assert_bit_identical(&dist, &reference);
    std::fs::remove_dir_all(&d).ok();
}

#[test]
fn world2_torn_publish_recovers_from_previous_delta() {
    let d = tmp("torn2");
    let reference = reference_report(&d, 2);
    // Rank 0 truncates its shard of delta 2 mid-file and crashes inside
    // the publish; recovery must refuse the torn delta and resume from
    // delta 1.
    let dist = dist_report(&d, 2, Some("torn:rank=0,seq=2"));
    assert_eq!(counter(&dist, "recoveries"), 1);
    assert!(counter(&dist, "replayed_steps") > 0);
    assert_bit_identical(&dist, &reference);
    std::fs::remove_dir_all(&d).ok();
}

#[test]
fn world4_kill_recovers_bit_identically() {
    let d = tmp("kill4");
    let reference = reference_report(&d, 4);
    let dist = dist_report(&d, 4, Some("kill:rank=2,step=8"));
    assert_eq!(counter(&dist, "recoveries"), 1);
    assert!(counter(&dist, "replayed_steps") > 0);
    assert_bit_identical(&dist, &reference);
    std::fs::remove_dir_all(&d).ok();
}
