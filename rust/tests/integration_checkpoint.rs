//! Integration: checkpoint save → reshard → resume, across world-size
//! changes, including optimizer-state continuity.

use mtgrboost::checkpoint::{
    files_to_read, install_rows, load_dense, load_meta, load_sparse_shard, save,
    CheckpointMeta,
};
use mtgrboost::embedding::dynamic_table::{DynamicEmbeddingTable, DynamicTableConfig};
use mtgrboost::embedding::sharded::shard_owner;
use mtgrboost::embedding::EmbeddingStore;
use mtgrboost::optim::adam::{AdamParams, DenseAdam, SparseAdam};
use mtgrboost::util::rng::Xoshiro256;

const DIM: usize = 4;

fn tmp(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("mtgr_it_ckpt_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    d
}

/// Build a sharded "trained" state: tables + sparse optimizer per rank.
fn trained_world(
    world: usize,
    ids: &[u64],
    updates: usize,
) -> Vec<(DynamicEmbeddingTable, SparseAdam)> {
    let mut shards: Vec<(DynamicEmbeddingTable, SparseAdam)> = (0..world)
        .map(|_| {
            (
                DynamicEmbeddingTable::new(
                    DynamicTableConfig::new(DIM).with_capacity(128).with_seed(7),
                ),
                SparseAdam::new(DIM, AdamParams::default()),
            )
        })
        .collect();
    let mut buf = vec![0.0f32; DIM];
    for &id in ids {
        let r = shard_owner(id, world);
        let (t, o) = &mut shards[r];
        t.lookup_or_insert(id, &mut buf);
        for u in 0..updates {
            let g: Vec<f32> = (0..DIM).map(|j| ((id + j as u64) % 7 + u as u64) as f32 * 0.1).collect();
            o.step(t, &[id], &g, 1.0);
        }
    }
    shards
}

#[test]
fn resume_continues_adam_trajectory_exactly() {
    // Train id X with k steps, checkpoint, restore elsewhere, apply one
    // more identical step on both — rows must match exactly. This
    // proves optimizer state (m, v, t) survives the reshard.
    let dir = tmp("traj");
    let ids: Vec<u64> = (0..50).collect();
    let mut world_a = trained_world(2, &ids, 3);

    let meta = CheckpointMeta {
        world: 2,
        step: 3,
        model: "tiny".into(),
        dim: DIM,
        param_count: 4,
    };
    let dense_params = [0.1f32, 0.2, 0.3, 0.4];
    let mut dense_opt = DenseAdam::new(4, AdamParams::default());
    let mut dp = dense_params.to_vec();
    dense_opt.step(&mut dp, &[1.0; 4], 1.0);
    for (rank, (t, o)) in world_a.iter().enumerate() {
        save(
            &dir,
            &meta,
            rank,
            (rank == 0).then_some((&dp[..], &dense_opt)),
            t,
            o,
        )
        .unwrap();
    }

    // Restore onto 4 ranks.
    let meta2 = load_meta(&dir).unwrap();
    let mut world_b: Vec<(DynamicEmbeddingTable, SparseAdam)> = (0..4)
        .map(|r| {
            let rows = load_sparse_shard(&dir, &meta2, 4, r).unwrap();
            let mut t = DynamicEmbeddingTable::new(
                DynamicTableConfig::new(DIM).with_capacity(128).with_seed(1234),
            );
            let mut o = SparseAdam::new(DIM, AdamParams::default());
            install_rows(rows, &mut t, &mut o);
            (t, o)
        })
        .collect();

    // One more identical update to every id on both worlds.
    let g = vec![0.25f32; DIM];
    for &id in &ids {
        let (t, o) = &mut world_a[shard_owner(id, 2)];
        o.step(t, &[id], &g, 1.0);
        let (t, o) = &mut world_b[shard_owner(id, 4)];
        o.step(t, &[id], &g, 1.0);
    }
    let mut a = vec![0.0f32; DIM];
    let mut b = vec![0.0f32; DIM];
    for &id in &ids {
        world_a[shard_owner(id, 2)].0.lookup(id, &mut a);
        world_b[shard_owner(id, 4)].0.lookup(id, &mut b);
        assert_eq!(a, b, "id {id}: Adam trajectory diverged after reshard");
    }

    // Dense side restores exactly too.
    let (dp2, state) = load_dense(&dir, 4).unwrap();
    assert_eq!(dp2, dp);
    let mut restored = DenseAdam::new(4, AdamParams::default());
    restored.restore_state(&state).unwrap();
    let mut x1 = dp.clone();
    let mut x2 = dp2.clone();
    dense_opt.step(&mut x1, &[0.5; 4], 1.0);
    restored.step(&mut x2, &[0.5; 4], 1.0);
    assert_eq!(x1, x2);
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn every_world_transition_conserves_rows() {
    let dir = tmp("cons");
    let mut rng = Xoshiro256::new(9);
    let ids: Vec<u64> = (0..400).map(|_| rng.next_u64() >> 16).collect();
    for &old_w in &[1usize, 2, 8] {
        let shards = trained_world(old_w, &ids, 1);
        let meta = CheckpointMeta {
            world: old_w,
            step: 0,
            model: "t".into(),
            dim: DIM,
            param_count: 1,
        };
        let d_opt = DenseAdam::new(1, AdamParams::default());
        let total_rows: usize = shards.iter().map(|(t, _)| t.len()).sum();
        for (rank, (t, o)) in shards.iter().enumerate() {
            save(&dir, &meta, rank, (rank == 0).then_some((&[0.0][..], &d_opt)), t, o)
                .unwrap();
        }
        for &new_w in &[1usize, 4, 16] {
            let mut loaded = 0;
            for r in 0..new_w {
                loaded += load_sparse_shard(&dir, &meta, new_w, r).unwrap().len();
            }
            assert_eq!(loaded, total_rows, "{old_w} -> {new_w}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}

#[test]
fn partial_checkpoint_detected() {
    // A missing rank file must error, not silently drop rows.
    let dir = tmp("partial");
    let ids: Vec<u64> = (0..100).collect();
    let shards = trained_world(4, &ids, 1);
    let meta = CheckpointMeta {
        world: 4,
        step: 0,
        model: "t".into(),
        dim: DIM,
        param_count: 1,
    };
    let d_opt = DenseAdam::new(1, AdamParams::default());
    for (rank, (t, o)) in shards.iter().enumerate().take(3) {
        // rank 3's file intentionally missing
        save(&dir, &meta, rank, (rank == 0).then_some((&[0.0][..], &d_opt)), t, o)
            .unwrap();
    }
    // Scale-down to 1: must read all 4 files → error.
    assert!(load_sparse_shard(&dir, &meta, 1, 0).is_err());
    // files_to_read still enumerates what *should* exist.
    assert_eq!(files_to_read(4, 1, 0), vec![0, 1, 2, 3]);
    std::fs::remove_dir_all(dir).ok();
}
