//! Minimal, dependency-free stand-in for the `anyhow` crate, vendored so
//! the workspace builds against an offline registry. It covers exactly
//! the surface this repository uses: [`Error`], [`Result`], the
//! [`Context`] extension trait (on `Result` and `Option`), and the
//! `anyhow!` / `bail!` / `ensure!` macros.
//!
//! Semantics match `anyhow` where it matters here:
//! - `Error` does **not** implement `std::error::Error`, so the blanket
//!   `From<E: std::error::Error>` conversion powers `?` on `io::Error`
//!   and friends without conflicting with `From<Error> for Error`.
//! - Context is prepended `outer: inner`, and both `{}` and `{:#}`
//!   render the full chain (real `anyhow` reserves the chain for `{:#}`;
//!   callers here only ever grep the `{:#}` form in tests).

use std::fmt;

/// A type-erased error: a rendered message chain.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { msg: m.to_string() }
    }

    /// Prepend a context layer (`outer: inner`).
    pub fn context(self, ctx: impl fmt::Display) -> Error {
        Error {
            msg: format!("{ctx}: {}", self.msg),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // Render the full source chain up front; the cause types are not
        // preserved (no call site downcasts).
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            src = s.source();
        }
        Error { msg }
    }
}

/// `anyhow::Result<T>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(...)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error> {
        self.map_err(|e| Error::msg(format!("{ctx}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an error built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/path/xyz")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let err = io_fail().unwrap_err();
        assert!(!err.to_string().is_empty());
    }

    #[test]
    fn context_chains_render_in_both_forms() {
        let e: Result<()> = Err(anyhow!("inner {}", 7));
        let e = e.context("outer");
        let err = e.unwrap_err();
        assert_eq!(format!("{err}"), "outer: inner 7");
        assert_eq!(format!("{err:#}"), "outer: inner 7");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        assert_eq!(
            v.with_context(|| format!("missing {}", "x"))
                .unwrap_err()
                .to_string(),
            "missing x"
        );
        assert_eq!(Some(3u32).context("unused").unwrap(), 3);
    }

    #[test]
    fn ensure_and_bail() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "too big: {x}");
            if x == 0 {
                bail!("zero");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(12).unwrap_err().to_string(), "too big: 12");
        assert_eq!(f(0).unwrap_err().to_string(), "zero");
    }
}
