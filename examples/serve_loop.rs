//! The full train→sync→serve loop: run the online trainer with delta
//! sync enabled, then stand up a [`mtgrboost::serve::ServingReplica`]
//! over the sync dir and drive it with generated closed-loop traffic —
//! micro-batched embedding lookups + dense forwards, periodic delta
//! refreshes, and a mid-run log-structured compaction pass.
//!
//! Two witnesses close the loop:
//! * the replica's content checksum must equal the trainer report's
//!   `embedding_checksum` bit-for-bit (lean no-Adam serving state still
//!   reconstructs the exact trained rows), and
//! * after compaction folds the delta chain into a fresh `base_<seq>`,
//!   a cold replica bootstrapped from that base alone must carry the
//!   same checksum — compaction lost nothing.
//!
//! ```bash
//! cargo run --release --example serve_loop
//! ```

use mtgrboost::online::{AdmissionConfig, OnlineOptions};
use mtgrboost::runtime::Engine;
use mtgrboost::serve::{
    compact_chain, run_serve, CompactOptions, ReplicaOptions, ServeOptions, ServingReplica,
    TrafficConfig,
};
use mtgrboost::train::{Trainer, TrainerOptions};

fn main() -> anyhow::Result<()> {
    let sync_dir = std::env::temp_dir().join("mtgr_serve_loop_sync");
    std::fs::remove_dir_all(&sync_dir).ok();

    // 1. Train online: 8 sync intervals of 5 steps, each publishing a
    //    delta snapshot into the sync dir the replica will consume.
    let mut opts = TrainerOptions::new("tiny", 2, 0);
    opts.train.target_tokens = 512;
    opts.train.lr = 0.005;
    opts.generator.len_mu = 3.0;
    opts.generator.max_len = 64;
    opts.generator.new_user_rate = 0.3;
    opts.generator.new_item_rate = 0.3;
    opts.collect_gauc = false;
    opts.log_every = 10;
    let mut online = OnlineOptions::new(5);
    online.intervals = 8;
    online.feature_ttl = 15;
    online.admission = Some(AdmissionConfig::new(2, 0.1));
    online.day_every = 2;
    online.sync_dir = Some(sync_dir.clone());
    opts.online = Some(online);
    let train_report = Trainer::new(opts, Engine::reference(7)?)?.run()?;
    println!("=== trainer ===");
    println!("steps          : {}", train_report.steps.len());
    println!("resident rows  : {}", train_report.table_rows);
    println!(
        "trained checksum: {:#018x}",
        train_report.embedding_checksum
    );

    // 2. Serve: bootstrap the replica from the sync dir and push 512
    //    requests through it. Mid-run (`compact_every`) the delta chain
    //    is folded into a fresh base and the folded deltas pruned.
    let engine = Engine::reference(7)?;
    let serve_opts = ServeOptions {
        requests: 512,
        micro_batch: 8,
        refresh_every: 128,
        compact_every: 256,
        traffic: TrafficConfig {
            users: 50_000,
            qps: 4000.0,
            day_seconds: 2.0,
            ..TrafficConfig::default()
        },
        ..ServeOptions::default()
    };
    let report = run_serve(&sync_dir, &engine, &serve_opts)?;
    println!("\n=== serving ===");
    println!("requests       : {} in {} micro-batches", report.requests, report.micro_batches);
    println!(
        "latency        : p50 {:.3} ms, p99 {:.3} ms (mean {:.3} ms)",
        report.latency_ms.p50, report.latency_ms.p99, report.latency_ms.mean
    );
    println!(
        "throughput     : {:.0} req/s achieved ({:.0} req/s offered)",
        report.achieved_qps, report.offered_qps
    );
    println!(
        "lookups        : {} ({} resident, {} cold-miss), cache hit rate {:.1}%",
        report.stats.lookups,
        report.stats.resident,
        report.stats.missing,
        report.cache_hit_rate * 100.0
    );
    println!(
        "sync           : applied seq {} (step {}), {} compaction(s)",
        report.applied_seq, report.applied_step, report.compactions
    );
    assert!(report.compactions >= 1, "compaction pass should have run");
    assert_eq!(
        report.embedding_checksum, train_report.embedding_checksum,
        "replica diverged from the trainer"
    );
    println!("replica state matches the trainer bit-for-bit ✓");

    // 3. Cold restart from the compacted base: the chain was folded and
    //    pruned, so a fresh replica boots from `base_<seq>` alone — and
    //    must still carry the exact trained state.
    assert!(
        compact_chain(&sync_dir, &CompactOptions::default())?.is_none(),
        "everything is already folded; a second pass has nothing to do"
    );
    let cold = ServingReplica::open(&sync_dir, ReplicaOptions::default())?;
    assert_eq!(cold.applied_seq(), report.applied_seq);
    assert_eq!(cold.content_checksum(), train_report.embedding_checksum);
    println!(
        "cold restart from compacted base_{:05} reproduces it too ✓",
        cold.applied_seq()
    );
    std::fs::remove_dir_all(&sync_dir).ok();
    Ok(())
}
