//! Online learning: run the trainer as a continuous learner with
//! feature admission, TTL expiry and incremental delta sync, then
//! replay the deltas like a serving replica would and verify the
//! reconstructed state matches the trainer bit-for-bit.
//!
//! The manual replay below is the *minimal* consumer — it validates the
//! chain and folds each delta by hand to show the wire contract. The
//! production-shaped consumer lives in `rust/src/serve/`:
//! [`mtgrboost::serve::ServingReplica`] bootstraps from the newest
//! compacted base + delta chain, refreshes live, caches hot ids, and
//! answers lookup+forward traffic — see `examples/serve_loop.rs` and
//! `cargo run --release -- serve --sync-dir <dir>`.
//!
//! ```bash
//! cargo run --release --example online_train
//! ```

use mtgrboost::checkpoint::delta::{apply_delta, list_delta_seqs, load_delta_shard, validate_chain};
use mtgrboost::embedding::concurrent::ConcurrentDynamicTable;
use mtgrboost::embedding::dynamic_table::DynamicTableConfig;
use mtgrboost::online::{AdmissionConfig, OnlineOptions};
use mtgrboost::optim::adam::{AdamParams, SparseAdam};
use mtgrboost::runtime::Engine;
use mtgrboost::train::{Trainer, TrainerOptions};

fn main() -> anyhow::Result<()> {
    let engine = Engine::reference(7)?;
    let serving_dir = std::env::temp_dir().join("mtgr_online_example_sync");
    std::fs::remove_dir_all(&serving_dir).ok();

    // 1. Configure an online run: 12 sync intervals of 5 steps. IDs
    //    must be seen twice before they earn an embedding row (plus a
    //    10% lottery for brand-new hot IDs), rows untrained for 15
    //    steps expire, and every interval a delta snapshot lands in the
    //    "serving" directory.
    let mut opts = TrainerOptions::new("tiny", 2, 0);
    opts.train.target_tokens = 512;
    opts.train.lr = 0.005;
    opts.generator.len_mu = 3.0;
    opts.generator.max_len = 64;
    opts.generator.new_user_rate = 0.3;
    opts.generator.new_item_rate = 0.3;
    opts.collect_gauc = false;
    opts.log_every = 5;
    let mut online = OnlineOptions::new(5);
    online.intervals = 12;
    online.feature_ttl = 15;
    online.admission = Some(AdmissionConfig::new(2, 0.1));
    online.day_every = 2; // fresh IDs arrive every 2 stream chunks
    online.sync_dir = Some(serving_dir.clone());
    opts.online = Some(online);

    // 2. Train online.
    let report = Trainer::new(opts, engine)?.run()?;
    println!("\n=== online run ===");
    println!("steps         : {}", report.steps.len());
    println!(
        "admission     : {} admitted, {} rejected (one-shot IDs never allocate)",
        report.online_admitted, report.online_rejected
    );
    println!("TTL expiry    : {} stale rows retired", report.online_expired);
    println!(
        "delta sync    : {} rows in {:.1} KB across {} snapshots",
        report.online_synced_rows,
        report.online_sync_bytes as f64 / 1e3,
        list_delta_seqs(&serving_dir)?.len()
    );
    println!("resident rows : {}", report.table_rows);

    // 3. Serving side: validate the chain (gaps, torn dirs, step
    //    discontinuities all fail loudly here instead of silently
    //    serving stale rows), then replay every delta in order onto
    //    empty shards — exactly what a serving replica does after
    //    loading a base snapshot (here the base is the empty step-0
    //    state, so base_seq = 0 and base_step = 0).
    let chain = validate_chain(&serving_dir, 0, 0)?;
    assert!(!chain.is_empty(), "trainer emitted no deltas");
    let meta = &chain[0];
    let mut checksum = 0u64;
    for rank in 0..meta.world {
        let table = ConcurrentDynamicTable::new(
            DynamicTableConfig::new(meta.dim).with_capacity(1024).with_seed(1),
            8,
        );
        let mut opt = SparseAdam::new(meta.dim, AdamParams::default());
        for m in &chain {
            let (rows, removed) = load_delta_shard(&serving_dir, m, rank)?;
            apply_delta(&table, &mut opt, rows, &removed);
        }
        checksum = checksum.wrapping_add(table.content_checksum());
    }
    assert_eq!(
        checksum, report.embedding_checksum,
        "serving replica diverged from the trainer"
    );
    println!("\nserving replica reconstructed the exact trainer state ✓");
    std::fs::remove_dir_all(&serving_dir).ok();
    Ok(())
}
