//! End-to-end validation run (DESIGN.md / EXPERIMENTS.md §E2E).
//!
//! Trains the `small` GRM (d=128, 4 HSTU blocks + MMoE) on a synthetic
//! Meituan-like corpus across 2 simulated GPUs for a few hundred steps,
//! with all three layers composing for real: Pallas HSTU kernel (L1)
//! inside the JAX model (L2), AOT-compiled to HLO and executed from the
//! Rust coordinator (L3) with sharded dynamic embedding tables, dynamic
//! sequence balancing, two-stage dedup and weighted gradient averaging.
//!
//! Logs the loss curve + GAUC (Fig. 11's correctness signal) and writes
//! `bench_results/e2e_train.json`.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_train [steps]
//! ```

use mtgrboost::runtime::Engine;
use mtgrboost::train::{Trainer, TrainerOptions};
use mtgrboost::util::bench::BenchReport;
use mtgrboost::util::json::Json;

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(200);
    let engine = Engine::start_default()?;

    let mut opts = TrainerOptions::new("small", 2, steps);
    // Realistic (scaled) workload: mean length ≈ 90, max 256 (the
    // largest compiled bucket), long-tailed; see EXPERIMENTS.md for the
    // scaling rationale vs the paper's mean-600 production logs.
    opts.generator.len_mu = 4.3;
    opts.generator.len_sigma = 0.6;
    opts.generator.max_len = 256;
    opts.generator.num_users = 20_000;
    opts.generator.num_items = 10_000;
    opts.train.target_tokens = 1400;
    opts.train.lr = 0.003;
    opts.shard_capacity = 1 << 15;
    opts.log_every = 10;
    opts.gauc_warmup = steps / 3;

    let t0 = std::time::Instant::now();
    let report = Trainer::new(opts, engine)?.run()?;
    let wall = t0.elapsed().as_secs_f64();

    let (loss_ctr, loss_ctcvr) = report.final_losses();
    let head: f64 =
        report.steps[..10.min(report.steps.len())].iter().map(|s| s.loss_ctr).sum::<f64>()
            / 10.0_f64.min(report.steps.len() as f64);

    println!("\n=== e2e_train report ({steps} steps, {wall:.0}s wall) ===");
    println!("loss ctr      : {head:.4} -> {loss_ctr:.4}");
    println!("loss ctcvr    : -> {loss_ctcvr:.4}");
    println!(
        "GAUC          : ctr {:.4}  ctcvr {:.4}",
        report.gauc_ctr.unwrap_or(f64::NAN),
        report.gauc_ctcvr.unwrap_or(f64::NAN)
    );
    let dense_params = 1_349_128; // small preset (see manifest)
    let sparse_params = report.table_rows * 128;
    println!(
        "parameters    : dense ~{:.2}M + sparse {:.2}M ({} rows x 128) + 2x Adam state",
        dense_params as f64 / 1e6,
        sparse_params as f64 / 1e6,
        report.table_rows
    );
    println!(
        "throughput    : {:.1} samples/s wall | {:.1} samples/s simulated-A100x2",
        report.wall.samples_per_sec(),
        report.sim_samples_per_sec
    );
    println!(
        "dedup         : {} -> {} ids sent ({:.0}% saved)",
        report.dedup_volume.ids_raw,
        report.dedup_volume.ids_sent,
        100.0 * (1.0 - report.dedup_volume.ids_sent as f64
            / report.dedup_volume.ids_raw.max(1) as f64)
    );
    println!("\nphase decomposition:\n{}", report.phases.report());

    // Loss curve for EXPERIMENTS.md (Fig. 11 analogue).
    let mut rep = BenchReport::new("e2e_train");
    let curve: Vec<Json> = report
        .steps
        .iter()
        .step_by(5)
        .map(|s| {
            Json::from_pairs(vec![
                ("step", s.step.into()),
                ("loss_ctr", s.loss_ctr.into()),
                ("loss_ctcvr", s.loss_ctcvr.into()),
            ])
        })
        .collect();
    rep.add_metric("loss_curve", Json::Arr(curve));
    rep.add_metric("gauc_ctr", report.gauc_ctr.unwrap_or(f64::NAN).into());
    rep.add_metric("gauc_ctcvr", report.gauc_ctcvr.unwrap_or(f64::NAN).into());
    rep.add_metric("final_loss_ctr", loss_ctr.into());
    rep.add_metric("sparse_rows", report.table_rows.into());
    rep.add_metric("wall_seconds", wall.into());
    rep.add_metric(
        "wall_samples_per_sec",
        report.wall.samples_per_sec().into(),
    );
    rep.save()?;

    anyhow::ensure!(loss_ctr < head, "training must reduce the loss");
    println!("\ne2e OK: loss decreased and all layers composed.");
    Ok(())
}
