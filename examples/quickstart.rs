//! Quickstart: train a tiny GRM on 2 simulated GPUs for 30 steps.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Demonstrates the minimal public-API path: start the PJRT engine over
//! the AOT artifacts, configure the trainer, run, inspect the report.

use mtgrboost::runtime::Engine;
use mtgrboost::train::{Trainer, TrainerOptions};

fn main() -> anyhow::Result<()> {
    // 1. Start the execution engine over `artifacts/` (built once by
    //    `make artifacts`; Python never runs after that).
    let engine = Engine::start_default()?;

    // 2. Configure a run: tiny model, 2 simulated GPUs, 30 steps.
    //    Defaults enable every MTGRBoost feature (dynamic sequence
    //    balancing, two-stage dedup, automatic table merging).
    let mut opts = TrainerOptions::new("tiny", 2, 30);
    opts.train.target_tokens = 512; // tokens per device per step
    opts.train.lr = 0.005;
    opts.generator.len_mu = 3.0; // short sequences for a fast demo
    opts.generator.max_len = 64;
    opts.log_every = 5;

    // 3. Train.
    let report = Trainer::new(opts, engine)?.run()?;

    // 4. Inspect.
    let (loss_ctr, loss_ctcvr) = report.final_losses();
    println!("\n=== quickstart report ===");
    println!("final losses  : ctr {loss_ctr:.4}  ctcvr {loss_ctcvr:.4}");
    println!(
        "GAUC          : ctr {:?}  ctcvr {:?}",
        report.gauc_ctr, report.gauc_ctcvr
    );
    println!(
        "throughput    : {:.1} samples/s wall, {:.1} samples/s simulated-A100",
        report.wall.samples_per_sec(),
        report.sim_samples_per_sec
    );
    println!("sparse rows   : {}", report.table_rows);
    println!("\nwhere the time went:\n{}", report.phases.report());
    Ok(())
}
