//! Checkpoint resharding (§5.2): save a training state on 4 "GPUs" and
//! resume on 8 (and back down to 2), verifying every embedding row and
//! optimizer state lands on exactly one new owner via the modulo rule.
//!
//! ```bash
//! cargo run --release --example checkpoint_reshard
//! ```

use mtgrboost::checkpoint::{
    files_to_read, install_rows, load_dense, load_meta, load_sparse_shard, save,
    CheckpointMeta,
};
use mtgrboost::embedding::dynamic_table::{DynamicEmbeddingTable, DynamicTableConfig};
use mtgrboost::embedding::sharded::shard_owner;
use mtgrboost::embedding::EmbeddingStore;
use mtgrboost::optim::adam::{AdamParams, DenseAdam, SparseAdam};
use mtgrboost::util::rng::Xoshiro256;

const DIM: usize = 8;

fn build_shard(rank: usize, world: usize, ids: &[u64]) -> (DynamicEmbeddingTable, SparseAdam) {
    let mut table = DynamicEmbeddingTable::new(
        DynamicTableConfig::new(DIM).with_capacity(256).with_seed(42),
    );
    let mut opt = SparseAdam::new(DIM, AdamParams::default());
    let mut buf = vec![0.0f32; DIM];
    for &id in ids.iter().filter(|&&id| shard_owner(id, world) == rank) {
        table.lookup_or_insert(id, &mut buf);
        let g: Vec<f32> = (0..DIM).map(|j| (id + j as u64) as f32 * 0.01).collect();
        opt.step(&mut table, &[id], &g, 1.0);
    }
    (table, opt)
}

fn main() -> anyhow::Result<()> {
    let dir = std::env::temp_dir().join("mtgr_ckpt_example");
    std::fs::remove_dir_all(&dir).ok();

    // ---- "train" on 4 GPUs -------------------------------------------
    let old_world = 4;
    let mut rng = Xoshiro256::new(1);
    let ids: Vec<u64> = (0..2_000).map(|_| rng.next_u64() >> 20).collect();
    let meta = CheckpointMeta {
        world: old_world,
        step: 1234,
        model: "small".into(),
        dim: DIM,
        param_count: 16,
    };
    let params: Vec<f32> = (0..16).map(|i| i as f32 * 0.5).collect();
    let mut dense_opt = DenseAdam::new(16, AdamParams::default());
    let grads = vec![0.1f32; 16];
    let mut p = params.clone();
    dense_opt.step(&mut p, &grads, 1.0);

    let mut total_saved = 0usize;
    for rank in 0..old_world {
        let (table, opt) = build_shard(rank, old_world, &ids);
        total_saved += table.len();
        let dense = (rank == 0).then_some((&p[..], &dense_opt));
        save(&dir, &meta, rank, dense, &table, &opt)?;
    }
    println!("saved {total_saved} rows across {old_world} rank files + dense.bin");

    // ---- resume on 8, then 2 ------------------------------------------
    for new_world in [8usize, 2] {
        let meta2 = load_meta(&dir)?;
        let (p2, state) = load_dense(&dir, meta2.param_count)?;
        assert_eq!(p2, p);
        let mut restored_opt = DenseAdam::new(16, AdamParams::default());
        restored_opt.restore_state(&state)?;

        let mut total = 0usize;
        for new_rank in 0..new_world {
            let reads = files_to_read(meta2.world, new_world, new_rank);
            let rows = load_sparse_shard(&dir, &meta2, new_world, new_rank)?;
            let mut table = DynamicEmbeddingTable::new(
                DynamicTableConfig::new(DIM).with_capacity(256).with_seed(99),
            );
            let mut opt = SparseAdam::new(DIM, AdamParams::default());
            let n = rows.len();
            install_rows(rows, &mut table, &mut opt);
            total += table.len();
            if new_rank < 3 {
                println!(
                    "  world {new_world} rank {new_rank}: read old files {reads:?} -> {n} rows"
                );
            }
        }
        assert_eq!(total, total_saved, "no row lost or duplicated");
        println!(
            "resume on {new_world} GPUs OK: {total} rows redistributed, step {} resumes",
            meta2.step
        );
    }

    // The paper's concrete example: GPU 0 and GPU 8 of a 16-GPU resume
    // both read old GPU 0's file.
    assert_eq!(files_to_read(8, 16, 0), vec![0]);
    assert_eq!(files_to_read(8, 16, 8), vec![0]);
    println!("paper example verified: ranks 0 and 8 of 16 both read old rank 0");
    std::fs::remove_dir_all(&dir).ok();
    Ok(())
}
