//! Dynamic-ID scenario (§4.1): the production situation static tables
//! cannot handle — merchants update menus and new users arrive daily,
//! so feature-ID space grows at serving time.
//!
//! Streams 10 "days" of traffic. The dynamic hash table absorbs every
//! new ID (expanding its key structure, never moving embeddings); the
//! static baseline overflows into its accuracy-degrading default row;
//! MCH remaps until its fixed capacity forces evictions.
//!
//! ```bash
//! cargo run --release --example dynamic_ids
//! ```

use mtgrboost::data::generator::{GeneratorConfig, WorkloadGenerator};
use mtgrboost::data::schema::Schema;
use mtgrboost::embedding::dynamic_table::{DynamicEmbeddingTable, DynamicTableConfig};
use mtgrboost::embedding::mch::MchTable;
use mtgrboost::embedding::static_table::StaticEmbeddingTable;
use mtgrboost::embedding::EmbeddingStore;
use mtgrboost::util::bench::{BenchReport, Table};

fn main() -> anyhow::Result<()> {
    const DIM: usize = 16;
    let cfg = GeneratorConfig {
        num_users: 20_000,
        num_items: 10_000,
        new_user_rate: 0.10,
        new_item_rate: 0.05,
        len_mu: 3.5,
        ..Default::default()
    };
    let schema = Schema::meituan_like(DIM, 1);
    let mut gen = WorkloadGenerator::new(cfg.clone());

    // Static table provisioned for the day-0 population plus a small
    // headroom — the paper's dilemma: provision too little and new IDs
    // degrade to the default row, provision generously and memory is
    // wasted (and it *still* eventually overflows).
    let static_cap = (cfg.num_items as f64 * 1.02) as usize;
    let mut dynamic = DynamicEmbeddingTable::new(
        DynamicTableConfig::new(DIM).with_capacity(1024).with_seed(7),
    );
    let mut statik = StaticEmbeddingTable::new(DIM, static_cap, 7);
    let mut mch = MchTable::new(DIM, static_cap / 2, 7);

    let mut table = Table::new(
        "dynamic IDs over 10 days (item_id feature)",
        &[
            "day",
            "new ids seen",
            "dyn rows",
            "dyn expansions",
            "static fallbacks",
            "mch evictions",
            "dyn MB",
            "static MB",
        ],
    );

    let mut buf = vec![0.0f32; DIM];
    let mut seen = std::collections::HashSet::new();
    for day in 0..10 {
        let mut new_today = 0u64;
        for _ in 0..300 {
            let seq = gen.next_sequence(&schema);
            for tok in &seq.tokens {
                let item = tok[0];
                if seen.insert(item) {
                    new_today += 1;
                }
                dynamic.lookup_or_insert(item, &mut buf);
                statik.lookup_or_insert(item, &mut buf);
                mch.lookup_or_insert(item, &mut buf);
            }
        }
        table.row(&[
            day.to_string(),
            new_today.to_string(),
            dynamic.len().to_string(),
            dynamic.stats.expansions.to_string(),
            statik.default_fallbacks.to_string(),
            mch.evictions.to_string(),
            format!("{:.1}", dynamic.memory_bytes() as f64 / 1e6),
            format!("{:.1}", statik.memory_bytes() as f64 / 1e6),
        ]);
        gen.advance_day();
    }

    let mut rep = BenchReport::new("dynamic_ids");
    rep.add_table(table);
    rep.add_metric(
        "key_migration_bytes",
        dynamic.stats.expansion_bytes_moved.into(),
    );
    rep.add_metric(
        "value_bytes_avoided",
        dynamic.stats.expansion_bytes_avoided.into(),
    );
    rep.save()?;

    println!(
        "\nDynamic table grew to {} rows via {} expansions, moving only {:.1} KB of \
         keys (a static re-layout would have moved {:.1} MB of embeddings).",
        dynamic.len(),
        dynamic.stats.expansions,
        dynamic.stats.expansion_bytes_moved as f64 / 1e3,
        dynamic.stats.expansion_bytes_avoided as f64 / 1e6,
    );
    println!(
        "Static table served {} default-row fallbacks — each one a degraded \
         prediction the dynamic table avoided.",
        statik.default_fallbacks
    );
    Ok(())
}
