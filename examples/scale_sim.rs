//! Multi-node scaling preview (Fig. 17's shape in seconds, not hours):
//! throughput and speedup from 8 to 128 simulated A100s for GRM-4G and
//! GRM-110G.
//!
//! ```bash
//! cargo run --release --example scale_sim
//! ```

use mtgrboost::config::ModelConfig;
use mtgrboost::sim::{simulate, SimOptions};
use mtgrboost::util::bench::Table;

fn main() {
    let mut table = Table::new(
        "scaling preview (dynamic balancing + two-stage dedup)",
        &["model", "gpus", "seq/s", "speedup", "ideal", "% of ideal"],
    );
    for model in [ModelConfig::grm_4g(), ModelConfig::grm_110g()] {
        let mut base = None;
        for world in [8usize, 16, 32, 64, 128] {
            let mut opts = SimOptions::new(model.clone(), world);
            opts.steps = 20;
            let r = simulate(&opts);
            let b = *base.get_or_insert(r.throughput);
            let speedup = r.throughput / b;
            let ideal = world as f64 / 8.0;
            table.row(&[
                model.name.clone(),
                world.to_string(),
                format!("{:.0}", r.throughput),
                format!("{speedup:.2}x"),
                format!("{ideal:.0}x"),
                format!("{:.1}%", 100.0 * speedup / ideal),
            ]);
        }
    }
    println!("{}", table.render());
    println!(
        "Paper (Fig. 17): 62.75%-78.5% of ideal at 128 GPUs; embedding dim \
         hurts scaling more than FLOPs. Run `cargo bench --bench \
         fig17_scalability` for the full reproduction."
    );
}
